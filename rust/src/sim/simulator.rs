//! The discrete-event supercomputer simulator (replaces Batsim/SimGrid).
//!
//! Drives the event queue, the fluid network, the cluster state and the
//! per-job Fig-4 execution state machines, and invokes the scheduling
//! policy on the paper's triggers: a periodic tick (default 60 s, as in
//! the worked example of §3.1) plus job arrivals and completions.
//!
//! Determinism: given (workload, config, scheduler), a run is bit-for-bit
//! reproducible — events at equal timestamps are processed FIFO and all
//! state updates are ordered. No simulator state lives in a hash map:
//! the running set is a dense [`RunningSet`] slab, flow completions
//! dispatch in flow-id order, and flow ownership is encoded in each
//! flow's tag ([`crate::sim::jobexec::flow_tag`]) instead of a side map.
//!
//! Memory discipline: the event loop recycles its per-batch scratch
//! (the same-timestamp event batch, the completed-flow buffer, the
//! scheduler-view vectors), so a steady-state batch — network drain,
//! event dispatch, a no-launch scheduler pass — performs zero heap
//! allocations once warm (pinned by the counting-allocator tier in
//! `tests/alloc.rs`).

use crate::core::cancel::CancelToken;
use crate::core::job::{Job, JobId, JobRecord, JobRequest, JobState};

use crate::core::time::{Duration, Time};
use crate::platform::cluster::Cluster;
use crate::platform::flows::{Flow, FlowNetwork};
use crate::platform::placement::Placement;
use crate::platform::PlaceProbe;
use crate::platform::routing::Router;
use crate::platform::topology::{Topology, TopologyConfig};
use crate::sched::timeline::ResourceTimeline;
use crate::sched::{queue_index_map, QueueIndex, RunningInfo, SchedCtx, SchedView, Scheduler};
use crate::sim::events::{Event, EventQueue};
use crate::sim::jobexec::{decode_flow_tag, flow_tag, stage_transfers, FlowKind, RunningJob};
use crate::sim::running::RunningSet;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub topo: TopologyConfig,
    /// Total burst-buffer capacity in bytes.
    pub bb_capacity: u64,
    /// How the burst-buffer pool places a job's bytes: the paper's
    /// shared striping (default), or per-node placement where a job's
    /// bytes must be carved group-locally next to its compute nodes and
    /// allocation can fail from fragmentation (the `per-node` scenario
    /// arch — set this from [`crate::platform::BbArch::placement`]).
    pub bb_placement: Placement,
    /// Scheduler tick period (paper: 1 minute).
    pub tick: Duration,
    /// Also invoke the scheduler on arrivals/completions (Batsim-style
    /// event triggers). The §3.1 worked example only needs the tick.
    pub event_triggers: bool,
    /// Simulate I/O side effects (stage-in/checkpoint/drain/stage-out
    /// through the contended network). When false, a job's runtime is
    /// exactly its ground-truth compute time — used by scheduler unit
    /// tests and plan-quality benches.
    pub io_enabled: bool,
    /// Hard stop (guards runaway configurations).
    pub horizon: Option<Time>,
    /// Record per-job node placements for Gantt export (Fig 3).
    pub record_gantt: bool,
    /// Rebuild the resource timeline from the running set on every
    /// scheduler invocation instead of using the incrementally
    /// maintained one — the pre-refactor cost model, kept as the perf
    /// baseline and the fingerprint-parity reference.
    pub rebuild_timeline: bool,
    /// Assert on every invocation that the incremental timeline is
    /// breakpoint-identical to a full rebuild (test paranoia mode; the
    /// check runs outside the `sched_wall` timing window).
    pub validate_timeline: bool,
    /// Cooperative cancellation: checked once per event batch. When the
    /// token fires mid-run the simulation stops promptly, returns with
    /// [`SimResult::cancelled`] set, and its records are partial — the
    /// campaign layer turns that into a failed (never a stored) outcome.
    pub cancel: CancelToken,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            topo: TopologyConfig::default(),
            bb_capacity: 0, // must be set by the caller (workload-dependent)
            bb_placement: Placement::Striped,
            tick: Duration::from_secs(60),
            event_triggers: true,
            io_enabled: true,
            horizon: None,
            record_gantt: false,
            rebuild_timeline: false,
            validate_timeline: false,
            cancel: CancelToken::new(),
        }
    }
}

/// One Gantt row: where and when a job ran.
#[derive(Debug, Clone)]
pub struct GanttEntry {
    pub job: JobId,
    pub start: Time,
    pub finish: Time,
    pub compute_nodes: Vec<usize>,
    /// Burst-buffer placement: (storage topology node id, bytes) per
    /// slice the job held — lets invariant tests audit per-storage-node
    /// occupancy and slice locality over the whole run.
    pub bb_nodes: Vec<(usize, u64)>,
}

/// Everything a simulation run produces.
#[derive(Debug)]
pub struct SimResult {
    pub policy: String,
    pub records: Vec<JobRecord>,
    pub makespan: Time,
    pub gantt: Vec<GanttEntry>,
    /// Number of scheduler invocations and the host wall-clock time spent
    /// inside them (the L3 perf metric for EXPERIMENTS.md §Perf).
    pub sched_invocations: u64,
    pub sched_wall: std::time::Duration,
    pub killed_jobs: u32,
    /// The run was stopped by its [`CancelToken`] before completing; the
    /// records (and therefore the fingerprint) cover only the simulated
    /// prefix and must not be treated as a full-run result.
    pub cancelled: bool,
}

impl SimResult {
    /// Order-sensitive FNV-1a hash over every record's simulation-time
    /// fields plus the makespan. Two runs agree on this fingerprint iff
    /// they produced identical per-job schedules, so it is the value the
    /// campaign layer's parallel-vs-sequential determinism checks (and
    /// its NDJSON records) rely on. Host wall-clock metrics are excluded.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
            }
        };
        for r in &self.records {
            mix(r.id.0 as u64);
            mix(r.submit.0);
            mix(r.start.0);
            mix(r.finish.0);
            mix(r.walltime.0);
            mix(r.procs as u64);
            mix(r.bb);
            mix(r.killed as u64);
        }
        mix(self.makespan.0);
        mix(self.killed_jobs as u64);
        h
    }
}

/// One scheduling decision an online session journals for its driver
/// (see [`Simulator::online`] / [`Simulator::take_decisions`]): batch
/// runs produce the same information as [`SimResult::records`], but a
/// long-lived service needs it *incrementally*, in event order, as the
/// clock is advanced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// The scheduler launched `job` at simulation time `t`.
    Started { job: JobId, t: Time },
    /// `job` left the machine at `t` (walltime-killed when `killed`).
    Finished { job: JobId, t: Time, killed: bool },
}

/// A point-in-time view of a live session, returned by
/// [`Simulator::stats`]. The serve layer renders `ok`/`query` response
/// blocks *and* the snapshot header from this one struct, so the wire
/// protocol and the snapshot schema cannot drift apart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionStats {
    /// The session clock (last advance target, or the latest event when
    /// cancelled mid-step).
    pub clock: Time,
    /// Jobs ever submitted to this simulator (batch or online).
    pub submitted: usize,
    /// Jobs waiting in the scheduler queue right now.
    pub pending: usize,
    /// Jobs executing on the machine right now.
    pub running: usize,
    /// Jobs that have left the machine (recorded), walltime kills
    /// included.
    pub completed: usize,
    /// Walltime-killed jobs so far (a subset of `completed`).
    pub killed: u32,
}

/// Why [`Simulator::pump`] stopped draining events.
enum PumpStop {
    /// The event queue is empty (batch mode only — online ticks re-arm).
    Drained,
    /// The next event lies beyond the requested limit (left queued).
    Limit,
    /// The cancel token fired.
    Cancelled,
    /// The hard-stop horizon event was reached.
    Horizon,
    /// Batch termination: no arrivals, pending or running jobs remain.
    Idle,
}

pub struct Simulator {
    cfg: SimConfig,
    topo: Topology,
    router: Router,
    net: FlowNetwork,
    cluster: Cluster,
    /// The shared availability timeline: owned here, maintained
    /// incrementally from the platform layer's allocation deltas, read
    /// (and tentatively written through transactions) by every policy.
    timeline: ResourceTimeline,
    jobs: Vec<Job>,
    clock: Time,
    queue: EventQueue,
    /// Pending queue in arrival order (scheduler sees this).
    pending: Vec<JobId>,
    /// Dense slab of running jobs — hash-free, deterministic iteration.
    running: RunningSet,
    records: Vec<JobRecord>,
    gantt: Vec<GanttEntry>,
    /// `Send` so whole sessions can migrate across the serve layer's
    /// work-stealing pump threads (the box is moved, never shared).
    scheduler: Box<dyn Scheduler + Send>,
    arrivals_left: usize,
    net_wake_gen: u64,
    flows_dirty: bool,
    gen_counter: u64,
    sched_invocations: u64,
    sched_wall: std::time::Duration,
    killed: u32,
    /// Online-session mode (see [`Simulator::online`]): the event loop
    /// is driven stepwise by [`Simulator::advance_to`] and fed by
    /// [`Simulator::submit`]; scheduler ticks re-arm unconditionally and
    /// decisions are journalled for the driver to drain.
    online: bool,
    decisions: Vec<Decision>,
    /// Empty-machine placement probe captured at session start, so
    /// online submissions are feasibility-checked against a clean
    /// cluster (the live probe reflects current occupancy, not
    /// schedulability — an unplaceable job would pend forever).
    empty_probe: Option<PlaceProbe>,
    // --- recycled event-loop scratch (steady state allocates nothing) ---
    /// Same-timestamp event batch, taken/returned around dispatch.
    batch: Vec<Event>,
    /// Completed flows returned by [`FlowNetwork::advance_into`].
    done_flows: Vec<Flow>,
    /// Scheduler-view snapshot buffers rebuilt per invocation.
    view_queue: Vec<JobRequest>,
    view_running: Vec<RunningInfo>,
}

impl Simulator {
    /// `jobs` need not be sorted; they are indexed by `JobId` = position
    /// after sorting by submit time.
    pub fn new(
        mut jobs: Vec<Job>,
        scheduler: Box<dyn Scheduler + Send>,
        cfg: SimConfig,
    ) -> Simulator {
        assert!(cfg.bb_capacity > 0 || jobs.iter().all(|j| j.bb == 0),
            "bb_capacity must be set when jobs request burst buffers");
        jobs.sort_by_key(|j| (j.submit, j.id.0));
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = JobId(i as u32);
            j.validate().expect("invalid job");
        }
        let topo = Topology::build(cfg.topo.clone());
        let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity).collect();
        let cluster = Cluster::with_placement(&topo, cfg.bb_capacity, cfg.bb_placement);
        // Every job must be schedulable on an empty machine — in
        // per-node mode that includes placement feasibility (an
        // unplaceable job would pend forever and the simulation would
        // tick without end), so the workload layer's per-node clamp is
        // enforced loudly here.
        let empty_probe = cluster.probe();
        for j in &jobs {
            assert!(
                cluster.capacity().fits(&j.request()),
                "job {} requests more than cluster capacity", j.id
            );
            assert!(
                empty_probe.can_place(&j.request()),
                "job {} is placement-infeasible even on an empty cluster", j.id
            );
        }
        let mut queue = EventQueue::new();
        for j in &jobs {
            queue.push(j.submit, Event::JobArrival(j.id));
        }
        queue.push(Time::ZERO, Event::SchedulerTick);
        if let Some(h) = cfg.horizon {
            queue.push(h, Event::Horizon);
        }
        let arrivals_left = jobs.len();
        let timeline = match cfg.bb_placement {
            Placement::Striped => ResourceTimeline::new(Time::ZERO, cluster.capacity()),
            Placement::PerNode => {
                let mut tl = ResourceTimeline::with_per_node(
                    Time::ZERO,
                    cluster.capacity(),
                    &cluster.bb.group_capacities(),
                );
                // Static compute topology unlocks split-share probes and
                // the plan scorer's group lane.
                tl.set_compute_group_caps(&cluster.compute.capacity_by_group());
                tl
            }
        };
        Simulator {
            router: Router::new(&topo),
            net: FlowNetwork::new(caps),
            timeline,
            cluster,
            topo,
            jobs,
            clock: Time::ZERO,
            queue,
            pending: Vec::new(),
            running: RunningSet::new(),
            records: Vec::new(),
            gantt: Vec::new(),
            scheduler,
            arrivals_left,
            net_wake_gen: 0,
            flows_dirty: false,
            gen_counter: 0,
            sched_invocations: 0,
            sched_wall: std::time::Duration::ZERO,
            cfg,
            killed: 0,
            online: false,
            decisions: Vec::new(),
            empty_probe: None,
            batch: Vec::new(),
            done_flows: Vec::new(),
            view_queue: Vec::new(),
            view_running: Vec::new(),
        }
    }

    /// Start a live (online) session: an empty simulator whose clock is
    /// driven stepwise by [`Simulator::advance_to`] and whose workload
    /// arrives through [`Simulator::submit`]. All scheduler state (the
    /// incremental timeline, a plan policy's incumbent plan, arena and
    /// warm-start seed) stays hot inside the boxed scheduler between
    /// steps — this is the `repro serve` entry point.
    pub fn online(scheduler: Box<dyn Scheduler + Send>, cfg: SimConfig) -> Simulator {
        let mut sim = Simulator::new(Vec::new(), scheduler, cfg);
        sim.online = true;
        // The cluster is still empty here: this probe answers "could the
        // job ever be placed", which `new` asserts per batch job and
        // `submit` must turn into a recoverable error instead.
        sim.empty_probe = Some(sim.cluster.probe());
        sim
    }

    /// Drain the event loop: process whole same-timestamp batches while
    /// the next batch lies at or before `limit` (`None` = unbounded).
    /// This is the single event-processing path — `run` calls it
    /// unbounded, [`Simulator::advance_to`] calls it with the session's
    /// target clock, leaving later events queued for the next step.
    fn pump(&mut self, limit: Option<Time>) -> PumpStop {
        loop {
            let Some(t) = self.queue.peek_time() else { return PumpStop::Drained };
            if limit.is_some_and(|lim| t > lim) {
                return PumpStop::Limit;
            }
            // One cancellation check per event batch: cheap (an atomic
            // load) yet prompt — the longest uncancellable stretch is a
            // single batch including its scheduler invocation.
            if self.cfg.cancel.is_cancelled() {
                return PumpStop::Cancelled;
            }
            debug_assert!(t >= self.clock, "event time regression");
            self.clock = t;
            // Drain network progress up to now; flow completions are part
            // of this batch.
            let mut trigger = self.drain_network();
            // Process every event scheduled for this exact timestamp as
            // one batch, then invoke the scheduler at most once. The
            // batch buffer is recycled across batches (handle() needs
            // &mut self, so it is taken out for the dispatch loop).
            let mut batch = std::mem::take(&mut self.batch);
            batch.clear();
            while self.queue.peek_time() == Some(t) {
                batch.push(self.queue.pop().unwrap().1);
            }
            let mut horizon = false;
            for &ev in &batch {
                match ev {
                    // Like the pre-extraction `break 'main`, the rest of
                    // the batch is abandoned with the horizon.
                    Event::Horizon => {
                        horizon = true;
                        break;
                    }
                    other => trigger |= self.handle(other),
                }
            }
            self.batch = batch;
            if horizon {
                return PumpStop::Horizon;
            }
            if trigger && !self.pending.is_empty() {
                self.invoke_scheduler();
            }
            self.reschedule_network_wake();
            // Online sessions never self-terminate: future submissions
            // may arrive, and the re-armed tick bounds the loop at
            // `limit` anyway.
            if !self.online
                && self.arrivals_left == 0
                && self.pending.is_empty()
                && self.running.is_empty()
            {
                return PumpStop::Idle;
            }
        }
    }

    /// Run to completion (all jobs finished or horizon reached).
    pub fn run(mut self) -> SimResult {
        assert!(!self.online, "run() is the batch entry point; online sessions use advance_to()");
        let stop = self.pump(None);
        let cancelled = matches!(stop, PumpStop::Cancelled);
        if matches!(stop, PumpStop::Horizon) {
            // Kill whatever is still running so records are complete —
            // in id order, so the horizon records (and the fingerprint)
            // are a pure function of the schedule, not of slab layout.
            let mut ids: Vec<JobId> = self.running.iter().map(|rj| rj.job.id).collect();
            ids.sort_unstable();
            for id in ids {
                self.kill_job(id);
            }
        }
        let makespan = self.records.iter().map(|r| r.finish).max().unwrap_or(Time::ZERO);
        SimResult {
            policy: self.scheduler.name().to_string(),
            records: self.records,
            makespan,
            gantt: self.gantt,
            sched_invocations: self.sched_invocations,
            sched_wall: self.sched_wall,
            killed_jobs: self.killed,
            cancelled,
        }
    }

    // ----- online-session API (the `repro serve` surface) ---------------

    /// Submit one job into a live session. The session assigns the next
    /// dense [`JobId`] (ignoring `job.id`); the job arrives at
    /// `job.submit`, which must not lie in the session's past. Unlike
    /// the batch constructor's asserts, every validation failure here is
    /// a recoverable `Err` — a service must survive bad client input.
    pub fn submit(&mut self, mut job: Job) -> Result<JobId, String> {
        assert!(self.online, "submit() is online-session API; batch jobs go through new()");
        let id = JobId(self.jobs.len() as u32);
        job.id = id;
        if job.submit < self.clock {
            return Err(format!(
                "submit time {} is in the session's past (clock {})",
                job.submit, self.clock
            ));
        }
        job.validate()?;
        if job.bb > 0 && self.cfg.bb_capacity == 0 {
            return Err("job requests burst buffer but the session has bb_capacity 0".into());
        }
        if !self.cluster.capacity().fits(&job.request()) {
            return Err(format!(
                "job requests {} but cluster capacity is {}",
                job.request(),
                self.cluster.capacity()
            ));
        }
        let probe = self.empty_probe.as_ref().expect("online sessions capture the empty probe");
        if !probe.can_place(&job.request()) {
            return Err("job is placement-infeasible even on an empty cluster".into());
        }
        self.queue.push(job.submit, Event::JobArrival(id));
        self.arrivals_left += 1;
        self.jobs.push(job);
        Ok(id)
    }

    /// Advance a live session's clock to `to`, processing every queued
    /// event up to and including it (launches, completions, scheduler
    /// ticks). Decisions made along the way are journalled — drain them
    /// with [`Simulator::take_decisions`]. Returns `true` when the
    /// session's [`CancelToken`] fired mid-step (the clock then rests at
    /// the cancellation point, not at `to`).
    pub fn advance_to(&mut self, to: Time) -> bool {
        assert!(self.online, "advance_to() is online-session API; batch runs use run()");
        debug_assert!(to >= self.clock, "advance target regresses the session clock");
        let stop = self.pump(Some(to));
        let cancelled = matches!(stop, PumpStop::Cancelled);
        if !cancelled && to > self.clock {
            // Settle on the target even when the last event lies before
            // it, so queries and subsequent submissions see clock == to.
            self.clock = to;
        }
        cancelled
    }

    /// Drain the decision journal accumulated since the last call, in
    /// event order. Online sessions only.
    pub fn take_decisions(&mut self) -> Vec<Decision> {
        std::mem::take(&mut self.decisions)
    }

    /// Completed-job records so far (online queries summarise these
    /// without waiting for a terminal [`SimResult`]).
    pub fn records(&self) -> &[JobRecord] {
        &self.records
    }

    /// The active policy's label.
    pub fn policy_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Jobs ever submitted to this simulator (batch or online).
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Walltime-killed jobs so far.
    pub fn n_killed(&self) -> u32 {
        self.killed
    }

    /// The point-in-time session view — the *single* accessor behind
    /// serve `ok`/`query` response blocks and the snapshot header
    /// (replacing the old `now`/`n_pending`/`n_running` trio, which let
    /// the two surfaces drift apart field by field).
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            clock: self.clock,
            submitted: self.jobs.len(),
            pending: self.pending.len(),
            running: self.running.len(),
            completed: self.records.len(),
            killed: self.killed,
        }
    }

    /// Every job ever submitted, in submission (= dense [`JobId`])
    /// order. Snapshotting persists these and replays them through
    /// [`Simulator::submit`] on restore; determinism does the rest.
    pub fn submitted_jobs(&self) -> &[Job] {
        &self.jobs
    }

    /// Toggle incumbent-plan journaling in the boxed scheduler (a no-op
    /// for policies without a plan). Serve sessions opened with
    /// `plan_deltas` turn this on to stream [`PlanUpdate`] lines.
    pub fn set_plan_journal(&mut self, on: bool) {
        self.scheduler.set_plan_journal(on);
    }

    /// Drain the scheduler's journalled plan updates since the last
    /// call, in invocation order. Empty for plan-less policies.
    pub fn take_plan_updates(&mut self) -> Vec<crate::sched::PlanUpdate> {
        self.scheduler.take_plan_updates()
    }

    /// Returns true when the event is a scheduler trigger.
    fn handle(&mut self, ev: Event) -> bool {
        match ev {
            Event::JobArrival(id) => {
                self.arrivals_left -= 1;
                self.pending.push(id);
                self.cfg.event_triggers
            }
            Event::SchedulerTick => {
                // Keep ticking while anything can still happen. Online
                // sessions tick unconditionally: a submission can arrive
                // at any future step, and `pump`'s limit bounds the
                // chain per advance.
                if self.online
                    || self.arrivals_left > 0
                    || !self.pending.is_empty()
                    || !self.running.is_empty()
                {
                    self.queue.push(self.clock + self.cfg.tick, Event::SchedulerTick);
                }
                true
            }
            Event::NetworkWake { gen } => {
                // Only a *fresh* wake is a trigger: completions at this
                // timestamp were already dispatched by drain_network at
                // the top of the batch. A stale wake (the flow set
                // changed after it was armed — e.g. a kill removed the
                // flows it announced) must not cause a scheduling pass:
                // nothing completed, and with event-driven policies an
                // extra pass at a phantom time changes launch decisions.
                gen == self.net_wake_gen && self.cfg.event_triggers
            }
            Event::ComputePhaseEnd { job, phase, gen } => self.on_phase_end(job, phase, gen),
            Event::WalltimeKill { job, gen } => {
                let valid = self
                    .running
                    .get(job)
                    .map(|rj| rj.gen == gen)
                    .unwrap_or(false);
                if valid {
                    self.kill_job(job);
                    self.cfg.event_triggers
                } else {
                    false
                }
            }
            Event::Horizon => unreachable!("handled in run()"),
        }
    }

    // ----- network ------------------------------------------------------

    fn drain_network(&mut self) -> bool {
        // advance_into hands back completions in ascending flow-id order
        // (creation order), so on_flow_done dispatch — and therefore
        // every downstream state change — is deterministic. Ownership is
        // decoded from the flow's tag; there is no side map to keep in
        // lock-step. The buffer is recycled across batches.
        let mut done = std::mem::take(&mut self.done_flows);
        self.net.advance_into(self.clock, &mut done);
        let mut trigger = false;
        for flow in &done {
            let (job, kind) = decode_flow_tag(flow.tag);
            trigger |= self.on_flow_done(job, kind, flow.id);
        }
        done.clear();
        self.done_flows = done;
        trigger
    }

    fn reschedule_network_wake(&mut self) {
        if self.flows_dirty {
            self.flows_dirty = false;
            self.net_wake_gen += 1;
        }
        if let Some(t) = self.net.next_completion() {
            self.queue.push(t, Event::NetworkWake { gen: self.net_wake_gen });
        }
    }

    /// Start the flows of one stage for a job. Returns the flow ids;
    /// empty when the job has no burst-buffer request (zero-byte stages
    /// complete instantly).
    fn start_stage_flows(&mut self, id: JobId, kind: FlowKind) -> Vec<u64> {
        let rj = self.running.get(id).expect("staging flows for a job that is not running");
        let slices: Vec<(usize, u64)> = rj
            .alloc
            .bb_slices
            .iter()
            .map(|s| (self.cluster.bb.storage_node_id(s.storage_idx), s.bytes))
            .collect();
        let transfers =
            stage_transfers(kind, &rj.alloc.compute_nodes, &slices, self.topo.pfs_node);
        let tag = flow_tag(id, kind);
        let mut ids = Vec::with_capacity(transfers.len());
        for (src, dst, bytes) in transfers {
            let route = self.router.route(&self.topo, src, dst);
            let fid = self.net.add_flow(route, bytes as f64, tag);
            ids.push(fid);
        }
        if !ids.is_empty() {
            self.flows_dirty = true;
        }
        ids
    }

    // ----- job lifecycle --------------------------------------------------

    fn launch(&mut self, id: JobId) {
        let job = self.jobs[id.0 as usize].clone();
        let req = job.request();
        let alloc = self
            .cluster
            .allocate(id, &req)
            .unwrap_or_else(|| panic!("scheduler launched {id} without resources"))
            .clone();
        self.gen_counter += 1;
        let gen = self.gen_counter;
        let rj = RunningJob::new(job.clone(), alloc, self.clock, gen);
        // Fold the platform layer's allocation delta into the shared
        // timeline: the job holds its resources until (at most) its
        // walltime bound. Hard asserts — a stale or wrong-job delta
        // would silently corrupt every later scheduling decision.
        let delta = self.cluster.take_delta();
        assert_eq!(delta.job, id);
        self.timeline.job_started_placed(
            id,
            delta.delta.magnitude(),
            &delta.bb_groups,
            self.clock,
            rj.kill_time(),
        );
        // One microsecond of grace so a job finishing exactly at its
        // walltime (perfect estimate, no I/O) completes rather than dies:
        // the kill event would otherwise win the FIFO tie.
        self.queue
            .push(rj.kill_time() + Duration(1), Event::WalltimeKill { job: id, gen });
        self.running.insert(rj);
        if self.online {
            self.decisions.push(Decision::Started { job: id, t: self.clock });
        }

        if self.cfg.io_enabled && job.bb > 0 {
            let flows = self.start_stage_flows(id, FlowKind::StageIn);
            debug_assert!(!flows.is_empty());
            let rj = self.running.get_mut(id).unwrap();
            rj.state = JobState::StageIn;
            rj.gating_flows = flows;
        } else if self.cfg.io_enabled {
            // No burst buffer: straight to compute.
            self.begin_compute_phase(id, 0);
        } else {
            // I/O disabled: one lumped compute interval.
            let end = self.clock + job.compute_time;
            let rj = self.running.get_mut(id).unwrap();
            rj.state = JobState::Compute { phase: job.phases - 1 };
            self.queue.push(end, Event::ComputePhaseEnd {
                job: id,
                phase: job.phases - 1,
                gen,
            });
        }
    }

    fn begin_compute_phase(&mut self, id: JobId, phase: u32) {
        let rj = self.running.get_mut(id).unwrap();
        rj.state = JobState::Compute { phase };
        let end = self.clock + rj.phase_duration(phase);
        let gen = rj.gen;
        self.queue.push(end, Event::ComputePhaseEnd { job: id, phase, gen });
    }

    fn on_phase_end(&mut self, id: JobId, phase: u32, gen: u64) -> bool {
        let Some(rj) = self.running.get(id) else { return false };
        if rj.gen != gen || rj.state != (JobState::Compute { phase }) {
            return false; // stale
        }
        let last = rj.is_last_phase(phase);
        let has_bb = rj.job.bb > 0 && self.cfg.io_enabled;
        if last {
            if has_bb {
                let flows = self.start_stage_flows(id, FlowKind::StageOut);
                let rj = self.running.get_mut(id).unwrap();
                rj.state = JobState::StageOut;
                if flows.is_empty() {
                    rj.stage_out_done = true;
                    if rj.is_complete() {
                        return self.complete_job(id);
                    }
                } else {
                    rj.gating_flows = flows;
                }
                false
            } else {
                self.complete_job(id)
            }
        } else if has_bb {
            // Checkpoint: computation suspends until it completes.
            let flows = self.start_stage_flows(id, FlowKind::Checkpoint);
            let rj = self.running.get_mut(id).unwrap();
            rj.state = JobState::Checkpoint { phase };
            if flows.is_empty() {
                self.begin_compute_phase(id, phase + 1);
            } else {
                rj.gating_flows = flows;
            }
            false
        } else {
            self.begin_compute_phase(id, phase + 1);
            false
        }
    }

    fn on_flow_done(&mut self, id: JobId, kind: FlowKind, flow: u64) -> bool {
        let Some(rj) = self.running.get_mut(id) else { return false };
        match kind {
            FlowKind::StageIn => {
                if rj.gating_flow_done(flow) {
                    self.begin_compute_phase(id, 0);
                }
                false
            }
            FlowKind::Checkpoint => {
                if rj.gating_flow_done(flow) {
                    let JobState::Checkpoint { phase } = rj.state else {
                        unreachable!("checkpoint flow outside checkpoint state")
                    };
                    // Async drain starts now; next compute phase runs
                    // concurrently with it (Fig 4).
                    let drains = self.start_stage_flows(id, FlowKind::Drain);
                    let rj = self.running.get_mut(id).unwrap();
                    rj.drain_flows.extend(drains);
                    self.begin_compute_phase(id, phase + 1);
                }
                false
            }
            FlowKind::StageOut => {
                if rj.gating_flow_done(flow) {
                    rj.stage_out_done = true;
                    if rj.is_complete() {
                        return self.complete_job(id);
                    }
                }
                false
            }
            FlowKind::Drain => {
                rj.drain_flow_done(flow);
                if rj.is_complete() {
                    return self.complete_job(id);
                }
                false
            }
        }
    }

    fn complete_job(&mut self, id: JobId) -> bool {
        let rj = self.running.remove(id).unwrap();
        debug_assert!(rj.gating_flows.is_empty() && rj.drain_flows.is_empty());
        self.record(&rj, false);
        self.cluster.release(id);
        // The release delta only bounds the buffer here: job_finished
        // already knows the held amount from its own running map.
        self.cluster.discard_deltas();
        // Early completion returns the walltime-bound tail to the
        // timeline.
        self.timeline.job_finished(id, self.clock);
        self.cfg.event_triggers
    }

    fn kill_job(&mut self, id: JobId) {
        let rj = self.running.remove(id).unwrap();
        for &fid in rj.gating_flows.iter().chain(rj.drain_flows.iter()) {
            self.net.remove_flow(fid);
            self.flows_dirty = true;
        }
        self.record(&rj, true);
        self.cluster.release(id);
        self.cluster.discard_deltas();
        self.timeline.job_finished(id, self.clock);
        self.killed += 1;
    }

    fn record(&mut self, rj: &RunningJob, killed: bool) {
        if self.online {
            self.decisions.push(Decision::Finished { job: rj.job.id, t: self.clock, killed });
        }
        self.records.push(JobRecord {
            id: rj.job.id,
            submit: rj.job.submit,
            start: rj.start,
            finish: self.clock,
            walltime: rj.job.walltime,
            procs: rj.job.procs,
            bb: rj.job.bb,
            killed,
        });
        if self.cfg.record_gantt {
            self.gantt.push(GanttEntry {
                job: rj.job.id,
                start: rj.start,
                finish: self.clock,
                compute_nodes: rj.alloc.compute_nodes.clone(),
                bb_nodes: rj
                    .alloc
                    .bb_slices
                    .iter()
                    .map(|s| (self.cluster.bb.storage_node_id(s.storage_idx), s.bytes))
                    .collect(),
            });
        }
    }

    // ----- scheduling ----------------------------------------------------

    fn invoke_scheduler(&mut self) {
        // The view snapshot buffers are recycled across invocations: a
        // steady-state no-launch pass refills warm capacity and
        // allocates nothing.
        self.view_queue.clear();
        self.view_queue
            .extend(self.pending.iter().map(|&id| self.jobs[id.0 as usize].as_request()));
        self.view_running.clear();
        self.view_running.extend(self.running.iter().map(|rj| RunningInfo {
            id: rj.job.id,
            req: rj.job.request(),
            expected_end: rj.kill_time(),
        }));
        // Slab order is deterministic but not id order; the view's order
        // is contractual for policies, so sort.
        self.view_running.sort_unstable_by_key(|r| r.id);
        let view = SchedView {
            now: self.clock,
            capacity: self.cluster.capacity(),
            free: self.cluster.free(),
            queue: &self.view_queue,
            running: &self.view_running,
        };
        if self.cfg.validate_timeline && !self.cfg.rebuild_timeline {
            // Paranoia mode, outside the timing window: the incremental
            // timeline must equal a full rebuild.
            self.timeline.advance_to(self.clock);
            self.timeline.assert_matches_view(&view);
        }
        // The id→queue-index map is lazy: built at most once per pass,
        // and only when a policy resolves an id or a launch needs
        // validating — no-launch ticks (the common case) pay nothing.
        let qindex = QueueIndex::new();
        let t0 = std::time::Instant::now();
        // Timeline work — advance, or the baseline's full rebuild — is
        // policy-side cost and stays inside the timed window so
        // `sched_wall` is comparable across modes.
        if self.cfg.rebuild_timeline {
            self.timeline.rebuild_from_view(&view);
        }
        let launches = {
            let mut ctx = SchedCtx::new(view, &mut self.timeline, &qindex)
                .with_probe(self.cluster.probe());
            self.scheduler.schedule(&mut ctx)
        };
        self.sched_wall += t0.elapsed();
        self.sched_invocations += 1;
        if launches.is_empty() {
            return;
        }
        let qmap = qindex.get_or_init(|| queue_index_map(&self.view_queue));
        // Launch batches are tiny; a linear dup-scan beats hashing.
        let mut launched: Vec<JobId> = Vec::with_capacity(launches.len());
        for &id in &launches {
            assert!(
                qmap.contains_key(&id),
                "scheduler launched non-pending {id}"
            );
            assert!(!launched.contains(&id), "scheduler launched {id} twice");
            launched.push(id);
            let req = self.jobs[id.0 as usize].request();
            assert!(
                self.cluster.fits_now(&req),
                "scheduler over-committed: {id} needs {req} but only {} free",
                self.cluster.free()
            );
            // Per-node mode: the policy's probe mirrors the allocator,
            // so a launch that fails here is a policy bug (it skipped
            // the `try_place_now` gate), not a legal race.
            assert!(
                self.cluster.can_place(&req),
                "scheduler launched {id} but its burst buffer is placement-infeasible"
            );
            self.launch(id);
        }
        // One O(Q) sweep instead of a remove() per launch.
        self.pending.retain(|id| !launched.contains(id));
    }

    /// Test/diagnostic hooks. (The old `n_running`/`n_pending`/`now`
    /// accessors became the one [`Simulator::stats`] view — they are
    /// protocol surface now.)
    pub fn clock(&self) -> Time {
        self.clock
    }
    pub fn timeline(&self) -> &ResourceTimeline {
        &self.timeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::fcfs::Fcfs;
    use crate::core::resources::TIB;

    fn mk_job(id: u32, submit_s: u64, runtime_s: u64, procs: u32, bb: u64) -> Job {
        Job {
            id: JobId(id),
            submit: Time::from_secs(submit_s),
            walltime: Duration::from_secs(runtime_s * 4 + 3600),
            compute_time: Duration::from_secs(runtime_s),
            procs,
            bb,
            phases: 2,
        }
    }

    fn cfg(bb: u64) -> SimConfig {
        SimConfig { bb_capacity: bb, ..SimConfig::default() }
    }

    #[test]
    fn empty_workload_terminates() {
        let sim = Simulator::new(vec![], Box::new(Fcfs::new()), cfg(TIB));
        let res = sim.run();
        assert!(res.records.is_empty());
        assert_eq!(res.makespan, Time::ZERO);
    }

    #[test]
    fn single_job_runs_and_completes_with_io() {
        let jobs = vec![mk_job(0, 0, 600, 4, 10 * (1 << 30))];
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), cfg(TIB)).run();
        assert_eq!(res.records.len(), 1);
        let r = res.records[0];
        assert!(!r.killed);
        assert_eq!(r.start, Time::ZERO);
        // Runtime must exceed pure compute time (stage-in + checkpoint +
        // stage-out all move 10 GiB through the network).
        assert!(r.runtime() > Duration::from_secs(600), "runtime {}", r.runtime());
        // ... but not absurdly (plenty of bandwidth for one job).
        assert!(r.runtime() < Duration::from_secs(700), "runtime {}", r.runtime());
    }

    #[test]
    fn io_disabled_runtime_is_exact() {
        let jobs = vec![mk_job(0, 0, 600, 4, 1 << 30)];
        let mut c = cfg(TIB);
        c.io_enabled = false;
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert_eq!(res.records[0].runtime(), Duration::from_secs(600));
    }

    #[test]
    fn zero_bb_job_skips_staging() {
        let jobs = vec![mk_job(0, 0, 300, 2, 0)];
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), cfg(TIB)).run();
        assert_eq!(res.records[0].runtime(), Duration::from_secs(300));
    }

    #[test]
    fn fcfs_serialises_conflicting_jobs() {
        // Two jobs each needing 60 cpus: cannot overlap on 96.
        let jobs = vec![
            mk_job(0, 0, 600, 60, 0),
            mk_job(1, 0, 600, 60, 0),
        ];
        let mut c = cfg(TIB);
        c.io_enabled = false;
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        let (a, b) = (res.records[0], res.records[1]);
        assert_eq!(a.start, Time::ZERO);
        assert!(b.start >= a.finish, "b must wait for a");
    }

    #[test]
    fn bb_contention_serialises_even_with_free_cpus() {
        // Plenty of CPUs but BB capacity only fits one job at a time.
        let jobs = vec![
            mk_job(0, 0, 600, 4, 800 * (1 << 30)),
            mk_job(1, 0, 600, 4, 800 * (1 << 30)),
        ];
        let res =
            Simulator::new(jobs, Box::new(Fcfs::new()), cfg(1000 * (1 << 30))).run();
        let (a, b) = (res.records[0], res.records[1]);
        assert!(b.start >= a.finish, "bb must serialise: {:?} {:?}", a, b);
    }

    #[test]
    fn walltime_kill_fires() {
        let mut j = mk_job(0, 0, 600, 4, 0);
        j.walltime = Duration::from_secs(100); // far below compute time
        let res = Simulator::new(vec![j], Box::new(Fcfs::new()), cfg(TIB)).run();
        assert_eq!(res.killed_jobs, 1);
        let r = res.records[0];
        assert!(r.killed);
        // Killed at walltime + the 1 microsecond completion-tie grace.
        assert_eq!(r.runtime(), Duration::from_secs(100) + Duration(1));
    }

    #[test]
    fn deterministic_across_runs() {
        let jobs: Vec<Job> = (0..20)
            .map(|i| {
                let bb = ((i as u64 % 5) + 1) * (1 << 30);
                mk_job(i, (i as u64) * 30, 300 + (i as u64 * 37) % 400, 1 + (i % 8), bb)
            })
            .collect();
        let r1 = Simulator::new(jobs.clone(), Box::new(Fcfs::new()), cfg(8 * (1 << 30) * 4)).run();
        let r2 = Simulator::new(jobs, Box::new(Fcfs::new()), cfg(8 * (1 << 30) * 4)).run();
        assert_eq!(r1.records.len(), r2.records.len());
        for (a, b) in r1.records.iter().zip(&r2.records) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn incremental_timeline_matches_rebuild_throughout_a_run() {
        // validate_timeline asserts breakpoint-identity between the
        // incremental timeline and a full rebuild at every scheduler
        // invocation of a busy, killing, I/O-heavy run.
        let mut jobs: Vec<Job> = (0..30)
            .map(|i| {
                mk_job(
                    i,
                    (i as u64) * 20,
                    200 + (i as u64 * 53) % 700,
                    1 + (i % 10),
                    ((i as u64 % 4) + 1) * (1 << 30),
                )
            })
            .collect();
        // A couple of under-estimated walltimes so kills happen too.
        jobs[3].walltime = Duration::from_secs(100);
        jobs[11].walltime = Duration::from_secs(150);
        let mut c = cfg(64 * (1 << 30));
        c.validate_timeline = true;
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert_eq!(res.records.len(), 30);
        assert!(res.killed_jobs >= 2);
    }

    #[test]
    fn rebuild_mode_produces_identical_fingerprint() {
        let jobs: Vec<Job> = (0..25)
            .map(|i| mk_job(i, (i as u64) * 25, 150 + (i as u64 * 37) % 500, 1 + (i % 6), 0))
            .collect();
        let mut inc = cfg(TIB);
        inc.io_enabled = false;
        let mut reb = inc.clone();
        reb.rebuild_timeline = true;
        let a = Simulator::new(jobs.clone(), Box::new(Fcfs::new()), inc).run();
        let b = Simulator::new(jobs, Box::new(Fcfs::new()), reb).run();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn gantt_recording() {
        let jobs = vec![mk_job(0, 0, 60, 3, 0)];
        let mut c = cfg(TIB);
        c.record_gantt = true;
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert_eq!(res.gantt.len(), 1);
        assert_eq!(res.gantt[0].compute_nodes.len(), 3);
    }

    #[test]
    fn per_node_placement_serialises_fragmented_jobs() {
        // Default topology: 3 groups, 1200 bytes of BB => 400 per group.
        // Job 0 parks 350 bytes in group 0; job 1 wants 300 bytes and
        // best-fit also sends its 4 nodes to group 0 — aggregate free
        // (850) admits it, placement does not. Under shared striping
        // they overlap; under per-node placement job 1 must wait for
        // job 0 to release its group.
        let jobs = vec![mk_job(0, 0, 600, 4, 350), mk_job(1, 10, 100, 4, 300)];
        let mut shared = cfg(1200);
        shared.io_enabled = false;
        let mut pernode = shared.clone();
        pernode.bb_placement = Placement::PerNode;
        let s = Simulator::new(jobs.clone(), Box::new(Fcfs::new()), shared).run();
        assert!(
            s.records[1].start < s.records[0].finish,
            "shared striping must overlap the jobs"
        );
        let p = Simulator::new(jobs, Box::new(Fcfs::new()), pernode).run();
        assert_eq!(p.records.len(), 2);
        assert!(p.records.iter().all(|r| !r.killed));
        assert!(
            p.records[1].start >= p.records[0].finish,
            "per-node placement must serialise on group-0 storage: {:?}",
            p.records
        );
    }

    #[test]
    fn per_node_run_with_validation_and_io_completes() {
        // The incremental == rebuild scalar invariant (and the group
        // timelines) must survive a busy per-node run with kills and
        // real I/O. 400 bytes per group; requests stay placeable.
        let mut jobs: Vec<Job> = (0..24)
            .map(|i| {
                mk_job(
                    i,
                    (i as u64) * 20,
                    150 + (i as u64 * 53) % 500,
                    1 + (i % 8),
                    ((i as u64 % 5) + 1) * 60,
                )
            })
            .collect();
        jobs[5].walltime = Duration::from_secs(100); // force a kill
        let mut c = cfg(1200);
        c.bb_placement = Placement::PerNode;
        c.validate_timeline = true;
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert_eq!(res.records.len(), 24);
        assert!(res.killed_jobs >= 1);
    }

    #[test]
    #[should_panic(expected = "placement-infeasible even on an empty cluster")]
    fn per_node_rejects_unplaceable_workloads_loudly() {
        // 500 bytes cannot fit any single 400-byte group for a 4-node
        // job, so the workload is unschedulable — caught at
        // construction instead of ticking forever.
        let jobs = vec![mk_job(0, 0, 60, 4, 500)];
        let mut c = cfg(1200);
        c.bb_placement = Placement::PerNode;
        let _ = Simulator::new(jobs, Box::new(Fcfs::new()), c);
    }

    #[test]
    fn pre_cancelled_token_stops_before_any_event() {
        let jobs = vec![mk_job(0, 0, 10_000, 4, 0)];
        let mut c = cfg(TIB);
        c.cancel = CancelToken::new();
        c.cancel.cancel();
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert!(res.cancelled);
        assert!(res.records.is_empty());
    }

    #[test]
    fn uncancelled_run_reports_cancelled_false() {
        let jobs = vec![mk_job(0, 0, 60, 2, 0)];
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), cfg(TIB)).run();
        assert!(!res.cancelled);
        assert_eq!(res.records.len(), 1);
    }

    #[test]
    fn cancelling_a_parent_token_stops_the_run() {
        let campaign = CancelToken::new();
        let jobs = vec![mk_job(0, 0, 10_000, 4, 0)];
        let mut c = cfg(TIB);
        c.cancel = campaign.child();
        campaign.cancel();
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert!(res.cancelled);
    }

    #[test]
    fn online_session_matches_batch_run() {
        // Same workload, same policy: feeding jobs through the online
        // API and advancing past the makespan must reproduce the batch
        // run record-for-record (ids submitted in sorted order, so the
        // batch constructor's re-indexing is the identity).
        let jobs: Vec<Job> = (0..12)
            .map(|i| {
                mk_job(i, (i as u64) * 40, 200 + (i as u64 * 37) % 300, 1 + (i % 6),
                    (i as u64 % 3) << 28)
            })
            .collect();
        let mut c = cfg(TIB);
        c.io_enabled = false;
        let batch = Simulator::new(jobs.clone(), Box::new(Fcfs::new()), c.clone()).run();
        let mut live = Simulator::online(Box::new(Fcfs::new()), c);
        for j in &jobs {
            live.submit(j.clone()).unwrap();
        }
        assert!(!live.advance_to(Time::from_secs(100_000)));
        assert_eq!(live.records().len(), batch.records.len());
        for (a, b) in live.records().iter().zip(&batch.records) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn online_decisions_stream_identically_across_split_advances() {
        // Hot state: advancing 0→5000 in one step or four must journal
        // the same decisions in the same order — nothing is recomputed
        // or replayed per request.
        let mk = || {
            let mut c = cfg(TIB);
            c.io_enabled = false;
            Simulator::online(Box::new(Fcfs::new()), c)
        };
        let submit_all = |sim: &mut Simulator| {
            for i in 0..6u64 {
                sim.submit(mk_job(0, i * 120, 300, 30, 0)).unwrap();
            }
        };
        let mut one = mk();
        submit_all(&mut one);
        assert!(!one.advance_to(Time::from_secs(5000)));
        let whole = one.take_decisions();
        let mut two = mk();
        submit_all(&mut two);
        let mut stepped = Vec::new();
        for t in [600u64, 1200, 1800, 5000] {
            assert!(!two.advance_to(Time::from_secs(t)));
            stepped.extend(two.take_decisions());
        }
        assert_eq!(whole, stepped);
        assert!(whole.iter().any(|d| matches!(d, Decision::Started { .. })));
        assert!(whole.iter().any(|d| matches!(d, Decision::Finished { .. })));
    }

    #[test]
    fn online_submit_validates_instead_of_panicking() {
        let mut c = cfg(0);
        c.io_enabled = false;
        let mut sim = Simulator::online(Box::new(Fcfs::new()), c);
        // Burst buffer on a session with no bb capacity.
        assert!(sim.submit(mk_job(0, 0, 60, 2, 1)).is_err());
        // More processors than the cluster owns.
        assert!(sim.submit(mk_job(0, 0, 60, 10_000, 0)).is_err());
        // A legal job still goes through, with a fresh dense id.
        let id = sim.submit(mk_job(7, 5, 60, 2, 0)).unwrap();
        assert_eq!(id, JobId(0));
        assert!(!sim.advance_to(Time::from_secs(10)));
        // Submissions in the session's past are rejected.
        assert!(sim.submit(mk_job(0, 5, 60, 2, 0)).is_err());
    }

    #[test]
    fn online_tick_chain_survives_idle_periods() {
        // With event triggers off, the periodic tick is the only thing
        // that can ever launch a job — so if the tick chain died during
        // the idle stretch (the batch-mode re-arm condition), the late
        // submission would pend forever.
        let mut c = cfg(TIB);
        c.io_enabled = false;
        c.event_triggers = false;
        let mut sim = Simulator::online(Box::new(Fcfs::new()), c);
        assert!(!sim.advance_to(Time::from_secs(3600)));
        sim.submit(mk_job(0, 3600, 120, 4, 0)).unwrap();
        assert!(!sim.advance_to(Time::from_secs(7200)));
        assert_eq!(sim.records().len(), 1);
        // The tick at 3600 fired before the arrival was queued, so the
        // next tick (3660) launches it.
        assert_eq!(sim.records()[0].start, Time::from_secs(3660));
        assert_eq!(sim.clock(), Time::from_secs(7200));
    }

    #[test]
    fn online_advance_observes_cancellation() {
        let mut c = cfg(TIB);
        c.io_enabled = false;
        let token = CancelToken::new();
        c.cancel = token.child();
        let mut sim = Simulator::online(Box::new(Fcfs::new()), c);
        sim.submit(mk_job(0, 0, 600, 4, 0)).unwrap();
        assert!(!sim.advance_to(Time::from_secs(60)));
        token.cancel();
        assert!(sim.advance_to(Time::from_secs(10_000)));
        // Cancellation stops the step before the target clock.
        assert!(sim.clock() < Time::from_secs(10_000));
    }

    #[test]
    fn horizon_kills_stragglers() {
        let jobs = vec![mk_job(0, 0, 10_000, 4, 0)];
        let mut c = cfg(TIB);
        c.horizon = Some(Time::from_secs(500));
        let res = Simulator::new(jobs, Box::new(Fcfs::new()), c).run();
        assert_eq!(res.records.len(), 1);
        assert!(res.records[0].killed);
        assert!(res.makespan <= Time::from_secs(500));
    }

    /// Forwards to FCFS while recording the time of every scheduling
    /// pass — the stale-wake regression below asserts on *when* the
    /// scheduler ran, not just on what it decided.
    struct InvocationLog {
        inner: Fcfs,
        calls: std::sync::Arc<std::sync::Mutex<Vec<Time>>>,
    }

    impl Scheduler for InvocationLog {
        fn name(&self) -> &'static str {
            "fcfs"
        }
        fn schedule(&mut self, ctx: &mut SchedCtx<'_, '_>) -> Vec<JobId> {
            self.calls.lock().unwrap().push(ctx.now());
            self.inner.schedule(ctx)
        }
    }

    #[test]
    fn stale_network_wake_does_not_trigger_a_scheduling_pass() {
        // Job 0 pins 90 cpus for a long time; job 1 starts a ~100 GiB
        // stage-in and is walltime-killed 1 s in, which removes its
        // flows and leaves the network empty — but the NetworkWake
        // armed at launch for the stage-in's completion (tens of
        // seconds out) is still queued. That wake is stale (its gen
        // predates the kill's bump) and must NOT count as a scheduler
        // trigger: nothing completed at that time, and a phantom pass
        // could change event-triggered policies' decisions. Job 2 can
        // only launch once job 0 completes.
        let gib = 1u64 << 30;
        let long = mk_job(0, 0, 100_000, 90, 0);
        let mut io = mk_job(1, 0, 600, 4, 100 * gib);
        io.walltime = Duration::from_secs(1);
        let blocked = mk_job(2, 0, 100, 10, 0);
        let calls = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let sched = InvocationLog { inner: Fcfs::new(), calls: calls.clone() };
        let mut c = cfg(400 * gib);
        // Push the periodic tick out of the way: every pass inside the
        // run is then attributable to a specific event trigger.
        c.tick = Duration::from_secs(1_000_000);
        let res = Simulator::new(vec![long, io, blocked], Box::new(sched), c).run();

        assert_eq!(res.records.len(), 3);
        assert_eq!(res.killed_jobs, 1);
        let kill_t = Time::from_secs(1) + Duration(1);
        let rec = |id: u32| *res.records.iter().find(|r| r.id == JobId(id)).unwrap();
        assert_eq!(rec(1).finish, kill_t, "job 1 dies at walltime + grace");
        assert_eq!(rec(2).start, rec(0).finish, "job 2 waits for job 0");

        let calls = calls.lock().unwrap();
        assert!(calls.contains(&Time::ZERO), "initial tick pass");
        assert!(calls.contains(&kill_t), "kill is a fresh trigger");
        assert!(calls.contains(&rec(0).finish), "completion is a fresh trigger");
        // The interval between the kill and job 0's completion contains
        // no fresh trigger — only the stale wake. Before the fix it
        // caused a pass ~80 s in (the dead stage-in's completion time).
        let phantom: Vec<Time> = calls
            .iter()
            .copied()
            .filter(|&t| t > kill_t && t < rec(0).finish)
            .collect();
        assert!(phantom.is_empty(), "stale NetworkWake triggered passes at {phantom:?}");
    }
}
