//! Cooperative cancellation.
//!
//! A [`CancelToken`] is a cheap, cloneable flag shared between the code
//! that requests cancellation (a per-cell timeout watchdog, a campaign
//! driver, an embedding application) and the code that honours it (the
//! simulator event loop). Cancellation is *cooperative*: setting the
//! flag does nothing by itself; the simulation observes it at the next
//! event batch and winds down promptly, so the owning thread can be
//! `join`ed instead of detached.
//!
//! Tokens form a tree via [`CancelToken::child`]: a child reports
//! cancelled when either its own flag or any ancestor's flag is set.
//! The campaign runner gives every cell a child of the campaign-level
//! token, so one campaign-wide `cancel()` stops every in-flight cell
//! while a per-cell timeout cancels only its own simulation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shared cancellation flag. `Clone` shares the underlying flag: all
/// clones observe the same `cancel()`.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    parent: Option<Arc<CancelToken>>,
}

impl CancelToken {
    /// A fresh, un-cancelled token with no parent.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// True once this token — or any ancestor it was derived from via
    /// [`child`](CancelToken::child) — has been cancelled.
    pub fn is_cancelled(&self) -> bool {
        if self.flag.load(Ordering::Acquire) {
            return true;
        }
        match &self.parent {
            Some(p) => p.is_cancelled(),
            None => false,
        }
    }

    /// Derive a child token: cancelling the child does not affect this
    /// token, but cancelling this token (or its ancestors) cancels the
    /// child.
    pub fn child(&self) -> CancelToken {
        CancelToken {
            flag: Arc::new(AtomicBool::new(false)),
            parent: Some(Arc::new(self.clone())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_not_cancelled() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn clones_share_the_flag() {
        let a = CancelToken::new();
        let b = a.clone();
        b.cancel();
        assert!(a.is_cancelled());
    }

    #[test]
    fn parent_cancellation_reaches_children() {
        let campaign = CancelToken::new();
        let cell = campaign.child();
        assert!(!cell.is_cancelled());
        campaign.cancel();
        assert!(cell.is_cancelled());
    }

    #[test]
    fn child_cancellation_does_not_escape() {
        let campaign = CancelToken::new();
        let cell_a = campaign.child();
        let cell_b = campaign.child();
        cell_a.cancel();
        assert!(cell_a.is_cancelled());
        assert!(!cell_b.is_cancelled());
        assert!(!campaign.is_cancelled());
    }

    #[test]
    fn grandchildren_observe_root_cancellation() {
        let root = CancelToken::new();
        let leaf = root.child().child();
        root.cancel();
        assert!(leaf.is_cancelled());
    }
}
