//! Simulation time: a monotone clock with microsecond resolution.
//!
//! All simulator state is keyed by [`Time`] (absolute instants) and
//! [`Duration`] (non-negative spans). Integer microseconds keep the
//! discrete-event engine fully deterministic — no float drift in event
//! ordering — while still resolving sub-second I/O transfer completions.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Microseconds per second.
pub const MICROS_PER_SEC: u64 = 1_000_000;

/// An absolute simulation instant, in microseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

/// A non-negative span of simulation time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Duration(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);
    /// A sentinel "never" instant (used for unset deadlines / +inf).
    pub const MAX: Time = Time(u64::MAX);

    pub fn from_secs(s: u64) -> Time {
        Time(s * MICROS_PER_SEC)
    }
    pub fn from_secs_f64(s: f64) -> Time {
        debug_assert!(s >= 0.0, "negative absolute time {s}");
        Time((s * MICROS_PER_SEC as f64).round() as u64)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / MICROS_PER_SEC as f64
    }
    pub fn as_hours_f64(self) -> f64 {
        self.as_secs_f64() / 3600.0
    }
    /// Saturating difference `self - earlier` as a Duration.
    pub fn since(self, earlier: Time) -> Duration {
        Duration(self.0.saturating_sub(earlier.0))
    }
    pub fn min(self, other: Time) -> Time {
        Time(self.0.min(other.0))
    }
    pub fn max(self, other: Time) -> Time {
        Time(self.0.max(other.0))
    }
    pub fn is_finite(self) -> bool {
        self != Time::MAX
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);
    pub const MAX: Duration = Duration(u64::MAX);

    pub fn from_secs(s: u64) -> Duration {
        Duration(s * MICROS_PER_SEC)
    }
    pub fn from_mins(m: u64) -> Duration {
        Duration(m * 60 * MICROS_PER_SEC)
    }
    pub fn from_secs_f64(s: f64) -> Duration {
        debug_assert!(s >= 0.0, "negative duration {s}");
        Duration((s * MICROS_PER_SEC as f64).round() as u64)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / MICROS_PER_SEC as f64
    }
    pub fn as_hours_f64(self) -> f64 {
        self.as_secs_f64() / 3600.0
    }
    pub fn saturating_sub(self, other: Duration) -> Duration {
        Duration(self.0.saturating_sub(other.0))
    }
    pub fn min(self, other: Duration) -> Duration {
        Duration(self.0.min(other.0))
    }
    pub fn max(self, other: Duration) -> Duration {
        Duration(self.0.max(other.0))
    }
    /// Integer multiply with saturation (walltime scaling etc.).
    pub fn mul_f64(self, k: f64) -> Duration {
        debug_assert!(k >= 0.0);
        let v = self.0 as f64 * k;
        if v >= u64::MAX as f64 {
            Duration::MAX
        } else {
            Duration(v.round() as u64)
        }
    }
}

impl Add<Duration> for Time {
    type Output = Time;
    fn add(self, d: Duration) -> Time {
        Time(self.0.saturating_add(d.0))
    }
}
impl AddAssign<Duration> for Time {
    fn add_assign(&mut self, d: Duration) {
        self.0 = self.0.saturating_add(d.0);
    }
}
impl Sub<Time> for Time {
    type Output = Duration;
    /// Panics in debug if `rhs > self`; saturates in release.
    fn sub(self, rhs: Time) -> Duration {
        debug_assert!(rhs <= self, "time underflow: {self:?} - {rhs:?}");
        Duration(self.0.saturating_sub(rhs.0))
    }
}
impl Add<Duration> for Duration {
    type Output = Duration;
    fn add(self, d: Duration) -> Duration {
        Duration(self.0.saturating_add(d.0))
    }
}
impl AddAssign<Duration> for Duration {
    fn add_assign(&mut self, d: Duration) {
        self.0 = self.0.saturating_add(d.0);
    }
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if *self == Time::MAX {
            return write!(f, "+inf");
        }
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}
impl fmt::Display for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_round_trips() {
        assert_eq!(Time::from_secs(5).as_secs_f64(), 5.0);
        assert_eq!(Duration::from_mins(2), Duration::from_secs(120));
        assert_eq!(Time::from_secs_f64(1.5).0, 1_500_000);
    }

    #[test]
    fn arithmetic() {
        let t = Time::from_secs(10) + Duration::from_secs(5);
        assert_eq!(t, Time::from_secs(15));
        assert_eq!(t - Time::from_secs(10), Duration::from_secs(5));
        assert_eq!(Time::from_secs(3).since(Time::from_secs(10)), Duration::ZERO);
    }

    #[test]
    fn max_is_sticky() {
        assert_eq!(Time::MAX + Duration::from_secs(1), Time::MAX);
        assert!(!Time::MAX.is_finite());
        assert!(Time::from_secs(1).is_finite());
    }

    #[test]
    fn mul_f64_saturates() {
        assert_eq!(Duration::from_secs(10).mul_f64(1.5), Duration::from_secs(15));
        assert_eq!(Duration::MAX.mul_f64(2.0), Duration::MAX);
    }

    #[test]
    fn ordering_is_total() {
        let mut v = vec![Time::from_secs(3), Time::ZERO, Time::MAX, Time::from_secs(1)];
        v.sort();
        assert_eq!(v, vec![Time::ZERO, Time::from_secs(1), Time::from_secs(3), Time::MAX]);
    }
}
