//! Core vocabulary types shared by every layer: time, resources, jobs.

pub mod cancel;
pub mod job;
pub mod resources;
pub mod time;

pub use cancel::CancelToken;
pub use job::{Job, JobId, JobRecord, JobRequest, JobState};
pub use resources::{ResourceDelta, Resources, GIB, TIB};
pub use time::{Duration, Time, MICROS_PER_SEC};
