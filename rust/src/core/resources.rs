//! Two-dimensional cluster resources: processors and burst-buffer bytes.
//!
//! Every scheduling decision in this system is made against a
//! [`Resources`] pair — the paper's central point is that reserving only
//! one of the two dimensions (processors) leads to pathological schedules.

use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// Bytes in one gibibyte / tebibyte (burst-buffer sizes).
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// A quantity of cluster resources: `cpu` processors (the paper equates
/// one compute node with one processor) and `bb` bytes of shared
/// burst-buffer capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Resources {
    pub cpu: u32,
    pub bb: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu: 0, bb: 0 };

    pub fn new(cpu: u32, bb: u64) -> Resources {
        Resources { cpu, bb }
    }

    /// True iff `self` can satisfy `req` in both dimensions.
    pub fn fits(&self, req: &Resources) -> bool {
        self.cpu >= req.cpu && self.bb >= req.bb
    }

    /// Component-wise minimum.
    pub fn min(&self, other: &Resources) -> Resources {
        Resources { cpu: self.cpu.min(other.cpu), bb: self.bb.min(other.bb) }
    }

    /// Component-wise saturating subtraction.
    pub fn saturating_sub(&self, other: &Resources) -> Resources {
        Resources {
            cpu: self.cpu.saturating_sub(other.cpu),
            bb: self.bb.saturating_sub(other.bb),
        }
    }

    pub fn is_zero(&self) -> bool {
        self.cpu == 0 && self.bb == 0
    }

    /// Checked subtraction: `None` on underflow in either dimension.
    pub fn checked_sub(&self, other: &Resources) -> Option<Resources> {
        Some(Resources {
            cpu: self.cpu.checked_sub(other.cpu)?,
            bb: self.bb.checked_sub(other.bb)?,
        })
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources { cpu: self.cpu + o.cpu, bb: self.bb + o.bb }
    }
}
impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        self.cpu += o.cpu;
        self.bb += o.bb;
    }
}
impl Sub for Resources {
    type Output = Resources;
    /// Panics on underflow (debug and release): resource accounting bugs
    /// must never be silently absorbed.
    fn sub(self, o: Resources) -> Resources {
        Resources {
            cpu: self.cpu.checked_sub(o.cpu).expect("cpu resource underflow"),
            bb: self.bb.checked_sub(o.bb).expect("bb resource underflow"),
        }
    }
}
impl SubAssign for Resources {
    fn sub_assign(&mut self, o: Resources) {
        *self = *self - o;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}cpu/{:.2}GiB", self.cpu, self.bb as f64 / GIB as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_is_conjunctive() {
        let cap = Resources::new(4, 10 * TIB);
        assert!(cap.fits(&Resources::new(4, 10 * TIB)));
        assert!(cap.fits(&Resources::ZERO));
        assert!(!cap.fits(&Resources::new(5, 0)));
        assert!(!cap.fits(&Resources::new(0, 10 * TIB + 1)));
    }

    #[test]
    fn arithmetic_round_trips() {
        let a = Resources::new(3, 100);
        let b = Resources::new(1, 40);
        assert_eq!(a + b - b, a);
        assert_eq!(a.saturating_sub(&Resources::new(10, 1000)), Resources::ZERO);
        assert_eq!(a.checked_sub(&Resources::new(10, 0)), None);
        assert_eq!(a.min(&b), Resources::new(1, 40));
    }

    #[test]
    #[should_panic(expected = "cpu resource underflow")]
    fn sub_panics_on_underflow() {
        let _ = Resources::new(1, 0) - Resources::new(2, 0);
    }
}
