//! Two-dimensional cluster resources: processors and burst-buffer bytes.
//!
//! Every scheduling decision in this system is made against a
//! [`Resources`] pair — the paper's central point is that reserving only
//! one of the two dimensions (processors) leads to pathological schedules.

use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// Bytes in one gibibyte / tebibyte (burst-buffer sizes).
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// A quantity of cluster resources: `cpu` processors (the paper equates
/// one compute node with one processor) and `bb` bytes of shared
/// burst-buffer capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Resources {
    pub cpu: u32,
    pub bb: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu: 0, bb: 0 };

    pub fn new(cpu: u32, bb: u64) -> Resources {
        Resources { cpu, bb }
    }

    /// True iff `self` can satisfy `req` in both dimensions.
    pub fn fits(&self, req: &Resources) -> bool {
        self.cpu >= req.cpu && self.bb >= req.bb
    }

    /// Component-wise minimum.
    pub fn min(&self, other: &Resources) -> Resources {
        Resources { cpu: self.cpu.min(other.cpu), bb: self.bb.min(other.bb) }
    }

    /// Component-wise saturating subtraction.
    pub fn saturating_sub(&self, other: &Resources) -> Resources {
        Resources {
            cpu: self.cpu.saturating_sub(other.cpu),
            bb: self.bb.saturating_sub(other.bb),
        }
    }

    pub fn is_zero(&self) -> bool {
        self.cpu == 0 && self.bb == 0
    }

    /// Checked subtraction: `None` on underflow in either dimension.
    pub fn checked_sub(&self, other: &Resources) -> Option<Resources> {
        Some(Resources {
            cpu: self.cpu.checked_sub(other.cpu)?,
            bb: self.bb.checked_sub(other.bb)?,
        })
    }
}

/// A *signed* change to a [`Resources`] quantity — the unit of
/// communication between the platform layer (which emits one delta per
/// allocation/release) and the [`crate::sched::timeline`] subsystem
/// (which applies deltas to segments of the availability timeline
/// instead of rebuilding it from the running set).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct ResourceDelta {
    pub cpu: i64,
    pub bb: i128,
}

impl ResourceDelta {
    pub const ZERO: ResourceDelta = ResourceDelta { cpu: 0, bb: 0 };

    /// The delta of acquiring `r` (free resources shrink).
    pub fn acquire(r: Resources) -> ResourceDelta {
        ResourceDelta { cpu: -(r.cpu as i64), bb: -(r.bb as i128) }
    }

    /// The delta of releasing `r` (free resources grow).
    pub fn release(r: Resources) -> ResourceDelta {
        ResourceDelta { cpu: r.cpu as i64, bb: r.bb as i128 }
    }

    /// The inverse delta (undo).
    pub fn inverse(self) -> ResourceDelta {
        ResourceDelta { cpu: -self.cpu, bb: -self.bb }
    }

    /// True when both components are non-negative (a pure release).
    pub fn is_release(self) -> bool {
        self.cpu >= 0 && self.bb >= 0
    }

    pub fn is_zero(self) -> bool {
        self.cpu == 0 && self.bb == 0
    }

    /// Component-wise absolute magnitude as unsigned resources.
    pub fn magnitude(self) -> Resources {
        Resources { cpu: self.cpu.unsigned_abs() as u32, bb: self.bb.unsigned_abs() as u64 }
    }
}

impl std::ops::Neg for ResourceDelta {
    type Output = ResourceDelta;
    fn neg(self) -> ResourceDelta {
        self.inverse()
    }
}

impl Resources {
    /// Apply a signed delta; `None` on underflow (either dimension going
    /// negative) or overflow. Resource-accounting bugs must never be
    /// silently absorbed, so callers either unwrap loudly or recover
    /// deliberately.
    pub fn checked_apply(&self, d: ResourceDelta) -> Option<Resources> {
        let cpu = (self.cpu as i64).checked_add(d.cpu)?;
        let bb = (self.bb as i128).checked_add(d.bb)?;
        if cpu < 0 || bb < 0 || cpu > u32::MAX as i64 || bb > u64::MAX as i128 {
            return None;
        }
        Some(Resources { cpu: cpu as u32, bb: bb as u64 })
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources { cpu: self.cpu + o.cpu, bb: self.bb + o.bb }
    }
}
impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        self.cpu += o.cpu;
        self.bb += o.bb;
    }
}
impl Sub for Resources {
    type Output = Resources;
    /// Panics on underflow (debug and release): resource accounting bugs
    /// must never be silently absorbed.
    fn sub(self, o: Resources) -> Resources {
        Resources {
            cpu: self.cpu.checked_sub(o.cpu).expect("cpu resource underflow"),
            bb: self.bb.checked_sub(o.bb).expect("bb resource underflow"),
        }
    }
}
impl SubAssign for Resources {
    fn sub_assign(&mut self, o: Resources) {
        *self = *self - o;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}cpu/{:.2}GiB", self.cpu, self.bb as f64 / GIB as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_is_conjunctive() {
        let cap = Resources::new(4, 10 * TIB);
        assert!(cap.fits(&Resources::new(4, 10 * TIB)));
        assert!(cap.fits(&Resources::ZERO));
        assert!(!cap.fits(&Resources::new(5, 0)));
        assert!(!cap.fits(&Resources::new(0, 10 * TIB + 1)));
    }

    #[test]
    fn arithmetic_round_trips() {
        let a = Resources::new(3, 100);
        let b = Resources::new(1, 40);
        assert_eq!(a + b - b, a);
        assert_eq!(a.saturating_sub(&Resources::new(10, 1000)), Resources::ZERO);
        assert_eq!(a.checked_sub(&Resources::new(10, 0)), None);
        assert_eq!(a.min(&b), Resources::new(1, 40));
    }

    #[test]
    #[should_panic(expected = "cpu resource underflow")]
    fn sub_panics_on_underflow() {
        let _ = Resources::new(1, 0) - Resources::new(2, 0);
    }

    #[test]
    fn delta_round_trips() {
        let r = Resources::new(3, 100);
        let a = ResourceDelta::acquire(r);
        let b = ResourceDelta::release(r);
        assert_eq!(a.inverse(), b);
        assert_eq!(-b, a);
        assert!(b.is_release() && !a.is_release());
        assert_eq!(a.magnitude(), r);
        assert_eq!(b.magnitude(), r);
        let free = Resources::new(10, 500);
        assert_eq!(free.checked_apply(a), Some(Resources::new(7, 400)));
        assert_eq!(free.checked_apply(a).unwrap().checked_apply(b), Some(free));
    }

    #[test]
    fn delta_apply_catches_underflow() {
        let free = Resources::new(2, 50);
        assert_eq!(free.checked_apply(ResourceDelta::acquire(Resources::new(3, 0))), None);
        assert_eq!(free.checked_apply(ResourceDelta::acquire(Resources::new(0, 51))), None);
        assert!(ResourceDelta::ZERO.is_zero());
    }
}
