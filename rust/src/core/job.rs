//! Jobs: the unit of scheduling.
//!
//! A [`Job`] carries what the user submitted (walltime, processor count,
//! burst-buffer request) plus the hidden ground truth the simulator needs
//! (actual runtime, number of computation phases — the Fig-4 execution
//! model of the paper). Schedulers may only look at the user-visible part;
//! the simulator enforces this by handing schedulers [`JobRequest`] views.

use super::resources::Resources;
use super::time::{Duration, Time};

/// Dense job identifier (index into the workload's job table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u32);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// The paper's Fig-4 job execution model constants.
pub const MIN_PHASES: u32 = 1;
pub const MAX_PHASES: u32 = 10;

/// A job as submitted by a user plus simulation ground truth.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: JobId,
    /// Submission (arrival) time.
    pub submit: Time,
    /// User-declared upper bound on processing time; jobs are killed when
    /// they exceed it. Schedulers plan with this value.
    pub walltime: Duration,
    /// Ground-truth total *computation* time (excludes I/O); the simulator
    /// splits this across `phases` computation phases per Fig 4.
    pub compute_time: Duration,
    /// Requested processors (== compute nodes in the paper's model).
    pub procs: u32,
    /// Requested burst-buffer bytes (total across the job).
    pub bb: u64,
    /// Number of computation phases (1..=10). Phases are interleaved with
    /// checkpoints to the burst buffer.
    pub phases: u32,
}

impl Job {
    /// The two-dimensional resource request schedulers must reserve.
    pub fn request(&self) -> Resources {
        Resources { cpu: self.procs, bb: self.bb }
    }

    /// User-visible view for schedulers.
    pub fn as_request(&self) -> JobRequest {
        JobRequest {
            id: self.id,
            submit: self.submit,
            walltime: self.walltime,
            procs: self.procs,
            bb: self.bb,
        }
    }

    /// Validate workload-model invariants (used by workload loaders).
    pub fn validate(&self) -> Result<(), String> {
        if self.procs == 0 {
            return Err(format!("{}: zero processors", self.id));
        }
        if self.walltime == Duration::ZERO {
            return Err(format!("{}: zero walltime", self.id));
        }
        if self.compute_time == Duration::ZERO {
            return Err(format!("{}: zero compute time", self.id));
        }
        if !(MIN_PHASES..=MAX_PHASES).contains(&self.phases) {
            return Err(format!("{}: phases {} outside 1..=10", self.id, self.phases));
        }
        Ok(())
    }
}

/// What a scheduler is allowed to see about a pending job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobRequest {
    pub id: JobId,
    pub submit: Time,
    pub walltime: Duration,
    pub procs: u32,
    pub bb: u64,
}

impl JobRequest {
    pub fn request(&self) -> Resources {
        Resources { cpu: self.procs, bb: self.bb }
    }
    /// Burst-buffer bytes requested per processor — one of the paper's
    /// nine initial-candidate sort keys.
    pub fn bb_per_proc(&self) -> f64 {
        self.bb as f64 / self.procs.max(1) as f64
    }
}

/// Lifecycle of a job inside the simulator (Fig 4 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Submitted, waiting in the scheduler queue.
    Pending,
    /// Transferring input data PFS -> burst buffer.
    StageIn,
    /// Executing computation phase `phase` (0-based).
    Compute { phase: u32 },
    /// Checkpointing after phase `phase`: compute nodes -> burst buffer;
    /// computation is suspended.
    Checkpoint { phase: u32 },
    /// Transferring results burst buffer -> PFS.
    StageOut,
    /// Completed normally at the recorded time.
    Completed,
    /// Killed because it exceeded its walltime.
    Killed,
}

/// Everything the metrics layer needs about one finished job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobRecord {
    pub id: JobId,
    pub submit: Time,
    pub start: Time,
    pub finish: Time,
    pub walltime: Duration,
    pub procs: u32,
    pub bb: u64,
    pub killed: bool,
}

impl JobRecord {
    /// Waiting time: from submission to the start of stage-in.
    pub fn waiting(&self) -> Duration {
        self.start.since(self.submit)
    }
    /// Observed processing time (stage-in through stage-out; includes the
    /// I/O stretching the paper simulates).
    pub fn runtime(&self) -> Duration {
        self.finish.since(self.start)
    }
    /// Turnaround: submission to completion.
    pub fn turnaround(&self) -> Duration {
        self.finish.since(self.submit)
    }
    /// Bounded slowdown with the paper's 10-minute bound:
    /// `max(1, turnaround / max(runtime, 10 min))`.
    pub fn bounded_slowdown(&self) -> f64 {
        let bound = Duration::from_mins(10);
        let denom = self.runtime().max(bound).as_secs_f64();
        (self.turnaround().as_secs_f64() / denom).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        Job {
            id: JobId(7),
            submit: Time::from_secs(100),
            walltime: Duration::from_mins(30),
            compute_time: Duration::from_mins(20),
            procs: 4,
            bb: 1 << 30,
            phases: 3,
        }
    }

    #[test]
    fn request_view_hides_ground_truth() {
        let j = job();
        let r = j.as_request();
        assert_eq!(r.id, j.id);
        assert_eq!(r.walltime, j.walltime);
        assert_eq!(r.request(), Resources::new(4, 1 << 30));
        assert!((r.bb_per_proc() - (1u64 << 28) as f64).abs() < 1e-9);
    }

    #[test]
    fn validation_catches_degenerate_jobs() {
        let mut j = job();
        assert!(j.validate().is_ok());
        j.procs = 0;
        assert!(j.validate().is_err());
        let mut j = job();
        j.phases = 11;
        assert!(j.validate().is_err());
        let mut j = job();
        j.walltime = Duration::ZERO;
        assert!(j.validate().is_err());
    }

    #[test]
    fn record_metrics() {
        let r = JobRecord {
            id: JobId(1),
            submit: Time::from_secs(0),
            start: Time::from_secs(600),
            finish: Time::from_secs(900),
            walltime: Duration::from_mins(30),
            procs: 1,
            bb: 0,
            killed: false,
        };
        assert_eq!(r.waiting(), Duration::from_secs(600));
        assert_eq!(r.runtime(), Duration::from_secs(300));
        assert_eq!(r.turnaround(), Duration::from_secs(900));
        // runtime 300s < bound 600s => denom = 600; 900/600 = 1.5
        assert!((r.bounded_slowdown() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn bounded_slowdown_floors_at_one() {
        let r = JobRecord {
            id: JobId(1),
            submit: Time::from_secs(0),
            start: Time::from_secs(0),
            finish: Time::from_secs(60),
            walltime: Duration::from_mins(5),
            procs: 1,
            bb: 0,
            killed: false,
        };
        assert_eq!(r.bounded_slowdown(), 1.0);
    }
}
