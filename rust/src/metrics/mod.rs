//! Evaluation metrics (paper §4.2): per-policy summaries of waiting time
//! and bounded slowdown, letter-value quantiles, tail distributions and
//! the per-part normalised comparison.

pub mod normalized;
pub mod quantiles;
pub mod summary;
pub mod tail;

pub use normalized::{normalized_by_reference, NormalizedPart};
pub use quantiles::{bsld_letter_values, waiting_letter_values};
pub use summary::{summarize, PolicySummary};
pub use tail::{bsld_tail, waiting_tail};

use crate::core::job::JobRecord;

/// Waiting times in hours for a record set.
pub fn waiting_hours(records: &[JobRecord]) -> Vec<f64> {
    records.iter().map(|r| r.waiting().as_hours_f64()).collect()
}

/// Bounded slowdowns (10-minute bound, paper's definition).
pub fn bounded_slowdowns(records: &[JobRecord]) -> Vec<f64> {
    records.iter().map(|r| r.bounded_slowdown()).collect()
}
