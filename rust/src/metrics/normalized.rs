//! Per-part normalised comparison — Figs 11-12: the workload is split
//! into 16 three-week parts, each part is simulated under every policy,
//! per-part means are normalised by the sjf-bb reference, and the
//! distribution of the 16 normalised values is shown per policy.

use crate::stats::descriptive::{quantile, mean};

/// One policy's normalised per-part values plus box statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct NormalizedPart {
    pub policy: String,
    /// metric(policy, part) / metric(reference, part), one per part.
    pub values: Vec<f64>,
    pub mean: f64,
    pub median: f64,
    pub q1: f64,
    pub q3: f64,
    pub min: f64,
    pub max: f64,
}

/// Normalise `per_part` metric means by the `reference` policy's values.
/// Parts where the reference is ~0 are skipped (empty parts).
pub fn normalized_by_reference(
    policy: &str,
    per_part: &[f64],
    reference: &[f64],
) -> NormalizedPart {
    assert_eq!(per_part.len(), reference.len(), "part count mismatch");
    let values: Vec<f64> = per_part
        .iter()
        .zip(reference)
        .filter(|&(_, &r)| r > 1e-12)
        .map(|(&v, &r)| v / r)
        .collect();
    let (min, max) = values
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    NormalizedPart {
        policy: policy.to_string(),
        mean: mean(&values),
        median: quantile(&values, 0.5),
        q1: quantile(&values, 0.25),
        q3: quantile(&values, 0.75),
        min: if values.is_empty() { 0.0 } else { min },
        max: if values.is_empty() { 0.0 } else { max },
        values,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalisation_against_reference() {
        let policy = [2.0, 4.0, 6.0, 8.0];
        let reference = [1.0, 2.0, 3.0, 4.0];
        let n = normalized_by_reference("p", &policy, &reference);
        assert_eq!(n.values, vec![2.0, 2.0, 2.0, 2.0]);
        assert_eq!(n.median, 2.0);
        assert_eq!(n.min, 2.0);
        assert_eq!(n.max, 2.0);
    }

    #[test]
    fn reference_normalises_to_one() {
        let reference = [3.0, 5.0, 7.0];
        let n = normalized_by_reference("sjf-bb", &reference, &reference);
        assert!(n.values.iter().all(|&v| (v - 1.0).abs() < 1e-12));
    }

    #[test]
    fn zero_reference_parts_skipped() {
        let policy = [2.0, 4.0];
        let reference = [0.0, 2.0];
        let n = normalized_by_reference("p", &policy, &reference);
        assert_eq!(n.values, vec![2.0]);
    }

    #[test]
    fn box_stats_ordered() {
        let policy = [1.0, 2.0, 3.0, 4.0, 10.0];
        let reference = [1.0; 5];
        let n = normalized_by_reference("p", &policy, &reference);
        assert!(n.min <= n.q1 && n.q1 <= n.median && n.median <= n.q3 && n.q3 <= n.max);
    }
}
