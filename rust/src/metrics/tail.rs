//! Tail distributions — Figs 9-10 plot, for each policy, the individual
//! values of the 3000 highest waiting times / bounded slowdowns, which is
//! where fcfs-easy's dispersion and filler's near-starvation show up.

use crate::core::job::JobRecord;
use crate::metrics::{bounded_slowdowns, waiting_hours};
use crate::stats::descriptive::top_k_desc;

/// The paper's tail size.
pub const TAIL_K: usize = 3000;

pub fn waiting_tail(records: &[JobRecord], k: usize) -> Vec<f64> {
    top_k_desc(&waiting_hours(records), k)
}

pub fn bsld_tail(records: &[JobRecord], k: usize) -> Vec<f64> {
    top_k_desc(&bounded_slowdowns(records), k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobId;
    use crate::core::time::{Duration, Time};

    #[test]
    fn tails_are_descending_and_capped() {
        let records: Vec<JobRecord> = (0..100)
            .map(|i| JobRecord {
                id: JobId(i),
                submit: Time::ZERO,
                start: Time::from_secs((i as u64 * 97) % 5000),
                finish: Time::from_secs((i as u64 * 97) % 5000 + 60),
                walltime: Duration::from_secs(60),
                procs: 1,
                bb: 0,
                killed: false,
            })
            .collect();
        let t = waiting_tail(&records, 10);
        assert_eq!(t.len(), 10);
        for w in t.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(waiting_tail(&records, 3000).len(), 100);
        let b = bsld_tail(&records, 5);
        assert!(b.iter().all(|&x| x >= 1.0));
    }
}
