//! Letter-value quantile summaries — Figs 7-8 are letter-value
//! ("boxen") plots of waiting time and bounded slowdown per policy.

use crate::core::job::JobRecord;
use crate::metrics::{bounded_slowdowns, waiting_hours};
use crate::stats::descriptive::{letter_values, LetterValue};

/// Minimum tail points per letter level (Hofmann et al. use a confidence
/// rule; a fixed floor of 8 matches seaborn's default closely for our n).
const MIN_TAIL: usize = 8;

pub fn waiting_letter_values(records: &[JobRecord]) -> Vec<LetterValue> {
    letter_values(&waiting_hours(records), MIN_TAIL)
}

pub fn bsld_letter_values(records: &[JobRecord]) -> Vec<LetterValue> {
    letter_values(&bounded_slowdowns(records), MIN_TAIL)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobId;
    use crate::core::time::{Duration, Time};

    #[test]
    fn waiting_letter_values_monotone() {
        let records: Vec<JobRecord> = (0..512)
            .map(|i| JobRecord {
                id: JobId(i),
                submit: Time::ZERO,
                start: Time::from_secs(i as u64 * 60),
                finish: Time::from_secs(i as u64 * 60 + 600),
                walltime: Duration::from_secs(600),
                procs: 1,
                bb: 0,
                killed: false,
            })
            .collect();
        let lv = waiting_letter_values(&records);
        assert!(lv.len() >= 4);
        for w in lv.windows(2) {
            assert!(w[1].lower <= w[0].lower && w[1].upper >= w[0].upper);
        }
        let bl = bsld_letter_values(&records);
        assert!(bl.iter().all(|l| l.lower >= 1.0));
    }
}
