//! Per-policy summary statistics — the numbers behind Figs 5-6 (means
//! with 95% confidence intervals) and the headline comparison of §4.2.

use crate::core::job::JobRecord;
use crate::metrics::{bounded_slowdowns, waiting_hours};
use crate::stats::descriptive::{ci95_half_width, mean};

/// Summary of one policy's run over one workload.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySummary {
    pub policy: String,
    pub n_jobs: usize,
    pub n_killed: usize,
    /// Mean waiting time in hours + CI half-width (Fig 5).
    pub mean_wait_h: f64,
    pub wait_ci95: f64,
    /// Mean bounded slowdown + CI half-width (Fig 6).
    pub mean_bsld: f64,
    pub bsld_ci95: f64,
    /// Median waiting (hours) — plan-based may trade median for tail.
    pub median_wait_h: f64,
    /// 95th-percentile waiting (hours) — the tail the per-scenario
    /// aggregation reports alongside the mean.
    pub p95_wait_h: f64,
    /// Maximum waiting time in hours (starvation indicator).
    pub max_wait_h: f64,
    pub makespan_h: f64,
}

/// Compute the summary for one policy's records.
pub fn summarize(policy: &str, records: &[JobRecord]) -> PolicySummary {
    let waits = waiting_hours(records);
    let bslds = bounded_slowdowns(records);
    let mut sorted = waits.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = crate::stats::descriptive::quantile_sorted(&sorted, 0.5);
    let makespan = records
        .iter()
        .map(|r| r.finish.as_hours_f64())
        .fold(0.0f64, f64::max);
    PolicySummary {
        policy: policy.to_string(),
        n_jobs: records.len(),
        n_killed: records.iter().filter(|r| r.killed).count(),
        mean_wait_h: mean(&waits),
        wait_ci95: ci95_half_width(&waits),
        mean_bsld: mean(&bslds),
        bsld_ci95: ci95_half_width(&bslds),
        median_wait_h: median,
        p95_wait_h: crate::stats::descriptive::quantile_sorted(&sorted, 0.95),
        max_wait_h: sorted.last().copied().unwrap_or(0.0),
        makespan_h: makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobId;
    use crate::core::time::{Duration, Time};

    fn rec(submit: u64, start: u64, finish: u64) -> JobRecord {
        JobRecord {
            id: JobId(0),
            submit: Time::from_secs(submit),
            start: Time::from_secs(start),
            finish: Time::from_secs(finish),
            walltime: Duration::from_secs(finish - start),
            procs: 1,
            bb: 0,
            killed: false,
        }
    }

    #[test]
    fn summary_computes_means() {
        // Waits: 0h, 1h, 2h.
        let records = vec![
            rec(0, 0, 3600),
            rec(0, 3600, 7200),
            rec(0, 7200, 10800),
        ];
        let s = summarize("test", &records);
        assert_eq!(s.n_jobs, 3);
        assert!((s.mean_wait_h - 1.0).abs() < 1e-9);
        assert!((s.median_wait_h - 1.0).abs() < 1e-9);
        // Type-7 quantile on [0, 1, 2] at q=0.95: 1.9.
        assert!((s.p95_wait_h - 1.9).abs() < 1e-9);
        assert!((s.max_wait_h - 2.0).abs() < 1e-9);
        assert!((s.makespan_h - 3.0).abs() < 1e-9);
        assert!(s.wait_ci95 > 0.0);
        // All runtimes 1h > 10min bound; bsld = turnaround/runtime.
        assert!(s.mean_bsld >= 1.0);
    }

    #[test]
    fn empty_records() {
        let s = summarize("none", &[]);
        assert_eq!(s.n_jobs, 0);
        assert_eq!(s.mean_wait_h, 0.0);
    }
}
