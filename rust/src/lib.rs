//! # bbsched — Plan-based Job Scheduling with Shared Burst Buffers
//!
//! A full reproduction of Kopanski & Rzadca (Euro-Par 2021): a
//! discrete-event supercomputer simulator with a Dragonfly topology,
//! fluid I/O-contention model and shared burst buffers, six online
//! scheduling policies (FCFS, EASY variants with/without burst-buffer
//! reservations, a greedy filler, and plan-based scheduling with
//! simulated-annealing optimisation), and the measurement harness that
//! regenerates every figure of the paper's evaluation.
//!
//! Architecture (three layers, Python never on the scheduling path):
//! - L3 (this crate): coordinator — simulator, schedulers, metrics, CLI.
//! - L2 (`python/compile/model.py`): batched discretised plan scorer in
//!   JAX, AOT-lowered to HLO text under `artifacts/`.
//! - L1 (`python/compile/kernels/`): Pallas earliest-start kernel called
//!   by L2.
//! - [`runtime`]: loads the AOT artifacts via PJRT and serves scores to
//!   the simulated-annealing loop.
//! - [`campaign`]: declarative experiment grids over the scenario space
//!   (scheduler x seed x workload family x estimate model x BB
//!   architecture x bb-factor) executed on a work-stealing thread pool
//!   with a deterministic, machine-readable output contract.
//! - [`workload::scenario`]: the composable scenario engine — workload
//!   families (paper twin, arrival storms, I/O mixes, heavy-tailed BB,
//!   SWF replay), walltime-estimate models (exact → x10-sloppy) and
//!   burst-buffer architectures ([`platform::BbArch`]: shared pool,
//!   per-node *placement*, legacy per-node clamp), all materialised
//!   deterministically from a seed.
//! - [`platform::placement`]: locality-aware per-node burst-buffer
//!   placement — a [`platform::Placement`] policy on the pool (a job's
//!   bytes are carved into per-group demands co-located with its
//!   compute allocation; group-local exhaustion fails allocation even
//!   when aggregate free bytes suffice), a shared group-selection rule
//!   ([`platform::placement::choose_groups`]) so the scheduler-side
//!   [`platform::PlaceProbe`] predicts the allocator exactly, and
//!   per-group free-bytes timelines
//!   ([`sched::timeline::GroupBbTimelines`]) behind the conservative
//!   reservation probes (`earliest_fit_placed` / `reserve_placed`).
//!   Policies gate every "launch now" decision through the probe
//!   (`SchedCtx::try_place_now`), which is a no-op under the paper's
//!   shared architecture — shared runs are bit-identical to the
//!   placement-free engine.
//!
//! Scheduling data path (the `sched::timeline` subsystem):
//! - [`sched::timeline::ResourceTimeline`] — one piecewise-constant
//!   free-(processors, burst-buffer) timeline per simulation, **owned by
//!   the simulator** and maintained incrementally from the platform
//!   layer's allocation deltas (job start subtracts its request until
//!   the walltime bound; early completion adds the tail back) instead of
//!   being rebuilt from the running set on every invocation.
//! - [`sched::SchedCtx`] — the `Scheduler` trait boundary: a read-only
//!   `SchedView` snapshot + the cached timeline + an id→queue-index map.
//!   Policies make tentative reservations through a scoped
//!   [`sched::timeline::TimelineTxn`] that rolls back on drop
//!   (Algorithm 1's "drop all reservations" as scope exit).
//! - Parity: `SimConfig::{rebuild_timeline, validate_timeline}` keep the
//!   pre-refactor rebuild semantics available as a perf baseline and an
//!   every-invocation equality assertion; `tests/parity.rs` proves all
//!   policies fingerprint-identical across modes, and
//!   `benches/sched_bench.rs` emits `BENCH_sched.json` with the
//!   per-policy `sched_wall` trajectory (enforced by the CI
//!   `bench-gate` job against the committed baseline).
//!
//! Event-loop microarchitecture ([`sim::simulator`]):
//! - Batched dispatch — same-timestamp events are processed as one
//!   batch (network drain first, then FIFO event dispatch, then at most
//!   one scheduler invocation), and every per-batch buffer — the event
//!   batch, the completed-flow list, the scheduler-view snapshot — is
//!   recycled, so a warm steady-state batch performs zero heap
//!   allocations (pinned alongside the scorer tier in `tests/alloc.rs`).
//! - Hash-free state — the running set is a dense [`sim::RunningSet`]
//!   slab (`JobId -> slot` index, swap-remove + fix-up), flow ownership
//!   is packed into each flow's tag (`(job << 2) | kind`,
//!   [`sim::jobexec::flow_tag`]), and the fluid network stores flows in
//!   a sorted vector so completions dispatch — and rates freeze — in
//!   flow-id order. Nothing on the event path iterates a `HashMap`, so
//!   determinism is structural, not seed-dependent.
//! - Stale-event guards — generation counters invalidate queued events
//!   whose cause disappeared (a killed job's `NetworkWake`/phase-end);
//!   a stale wake is *not* a scheduler trigger.
//! - [`sched::timeline::Profile`] mutations coalesce only the two seams
//!   of the changed interval (O(1) after the binary-search splits)
//!   instead of sweeping every breakpoint per reservation.
//!
//! Plan-optimisation hot path ([`sched::plan`]):
//! - Delta scoring — SA neighbour moves re-score from their first
//!   changed position through the
//!   [`sched::plan::PermScorer::score_proposal`] /
//!   [`sched::plan::PermScorer::note_incumbent`] protocol, with
//!   `ExactScorer::cold` kept as the bit-exactness oracle.
//! - Allocation discipline — every per-proposal buffer (checkpoint
//!   profiles, scratch, group lanes, static share carvings) lives in a
//!   [`sched::plan::scorer::ScorerArena`] owned by the policy and
//!   recycled across invocations (`ExactScorer::new_in` /
//!   `into_arena`); once warm, scoring a proposal performs zero heap
//!   allocations (pinned by the counting allocator in `tests/alloc.rs`).
//! - Opt-in cost knobs that change trajectories: warm start
//!   (`--plan-warm-start`), queue windowing ([`sched::plan::window`],
//!   `--plan-window` / campaign `plan-windows` axis; the window picks
//!   the W most urgent jobs by XFactor, not the FCFS prefix), and
//!   group-aware scoring (`--plan-group-aware`: per-storage-group
//!   free-bytes lanes in the scorer so per-node fragmentation is
//!   anticipated in the plan instead of discovered at the launch
//!   probe; inert — fingerprint-identical — outside per-node
//!   placement).
//!
//! Run configuration and resumability:
//! - [`options::SimOptions`] — the single builder every entry point
//!   (CLI, campaign runner, benches, tests) uses to assemble simulator +
//!   scheduler knobs; new knobs are added once here instead of in five
//!   plumbing layers.
//! - [`core::cancel::CancelToken`] — cooperative cancellation observed
//!   by the simulator event loop; per-cell timeouts cancel and *join*
//!   their worker instead of detaching it.
//! - [`campaign::store`] — content-addressed on-disk store of completed
//!   campaign cells (`.repro-store/<fnv1a>.json`); re-runs skip cached
//!   cells byte-identically, `--force` recomputes, `repro gc` removes
//!   artifacts no longer reachable from a kept spec. The same store is
//!   the [`serve`] service's cache tier: `run` requests whose cell any
//!   previous campaign or serve session computed are answered from disk
//!   without simulating.
//! - [`serve`] — `repro serve`, the long-lived stdin/stdout NDJSON
//!   scheduling service: named online sessions
//!   ([`sim::simulator::Simulator::online`]) keep scheduler state hot
//!   between requests (incremental timeline, incumbent plan, scorer
//!   arena, warm-start seed); requests stream `submit`/`advance`/
//!   `query` and decisions stream back as events — plus opt-in
//!   `plan_delta`/`metrics` observability lines; every failure is a
//!   typed error line, and `--record`/`--replay` make any dialogue a
//!   byte-identical regression artifact. The service is restartable
//!   and concurrent without weakening that guarantee: sessions are
//!   whole movable values (`Simulator` owns a `Box<dyn Scheduler +
//!   Send>`), so `--session-jobs N` migrates them across the
//!   work-stealing [`pool`] to batch independent advances
//!   byte-identically, and `snapshot`/`restore` persist a session's
//!   event history through the run store — replaying it rebuilds the
//!   hot state bit-exactly (the split-advance invariant), so a
//!   restored session's response stream matches the never-killed
//!   one's.

pub mod campaign;
pub mod coordinator;
pub mod core;
pub mod metrics;
pub mod options;
pub mod platform;
pub mod pool;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod workload;

pub use crate::core::{CancelToken, Duration, Job, JobId, JobRecord, JobRequest, Resources, Time};
pub use crate::options::SimOptions;
