//! Statistical twin of the KTH-SP2-1996-2.1-cln workload.
//!
//! The original PWA log is not redistributable with this repository, so
//! experiments run on a generator that reproduces its published
//! characteristics (Feitelson et al., "Experience with the Parallel
//! Workloads Archive"): ~28,453 jobs over ~11 months on a 100-node SP2,
//! strong daily and weekly arrival cycles, long-tailed runtimes with
//! loose user walltime estimates, and mostly small, power-of-two-ish
//! processor requests. Burst-buffer requests come from the log-normal
//! [`BbModel`] exactly as the paper supplements the log (§4.1).
//!
//! The generator is seeded and deterministic; the DESIGN.md substitution
//! table documents why a statistical twin preserves the paper's findings.

use crate::core::job::{Job, JobId};
use crate::core::time::{Duration, Time};
use crate::stats::rng::Pcg32;
use crate::workload::bbmodel::BbModel;

/// Generator parameters (defaults = the paper's setup).
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub n_jobs: usize,
    /// Trace span in weeks (KTH-SP2 covers ~48).
    pub span_weeks: f64,
    /// Compute nodes in the simulated machine (paper: 96).
    pub max_procs: u32,
    /// Burst-buffer request model.
    pub bb_model: BbModel,
    /// Cap on one job's total burst-buffer request as a fraction of the
    /// cluster's capacity (jobs must remain schedulable).
    pub max_bb_capacity_fraction: f64,
    /// Total burst-buffer capacity (bytes); used with the fraction above.
    pub bb_capacity: u64,
    pub seed: u64,
}

impl SynthConfig {
    /// The paper-scale workload: 28,453 jobs over 48 weeks.
    pub fn paper(seed: u64) -> SynthConfig {
        let bb_model = BbModel::default();
        let bb_capacity = bb_model.capacity_for(96);
        SynthConfig {
            n_jobs: 28_453,
            span_weeks: 48.0,
            max_procs: 96,
            bb_model,
            max_bb_capacity_fraction: 0.8,
            bb_capacity,
            seed,
        }
    }

    /// A scaled-down version for tests/benches: `frac` of the jobs over
    /// `frac` of the span (keeps the load level comparable).
    pub fn scaled(seed: u64, frac: f64) -> SynthConfig {
        let mut c = SynthConfig::paper(seed);
        c.n_jobs = ((c.n_jobs as f64 * frac) as usize).max(10);
        c.span_weeks = (c.span_weeks * frac).max(0.2);
        c
    }
}

/// Relative arrival intensity for a time-of-week (hours in [0, 168)).
/// Day cycle peaks 09:00-17:00; weekend load drops to ~40%.
fn week_intensity(hour_of_week: f64) -> f64 {
    let day = (hour_of_week / 24.0) as usize; // 0 = Monday
    let hod = hour_of_week % 24.0;
    let daily = if (9.0..17.0).contains(&hod) {
        1.0
    } else if (6.0..9.0).contains(&hod) || (17.0..22.0).contains(&hod) {
        0.6
    } else {
        0.25
    };
    let weekly = if day >= 5 { 0.4 } else { 1.0 };
    daily * weekly
}

/// Sample the processor count: the PWA SP2 logs are dominated by small
/// powers of two, with a thin tail of large jobs (~11% of proc-time from
/// jobs >= 64 procs).
fn sample_procs(rng: &mut Pcg32, max_procs: u32) -> u32 {
    const SIZES: [u32; 8] = [1, 2, 4, 8, 16, 32, 64, 96];
    const WEIGHTS: [f64; 8] = [0.28, 0.14, 0.16, 0.17, 0.12, 0.08, 0.035, 0.015];
    let mut p = SIZES[rng.weighted(&WEIGHTS)];
    // 20% of jobs perturb off the power of two (real logs are not pure).
    if p > 1 && rng.bool(0.2) {
        let jitter = rng.range_u32(0, p / 2);
        p = (p - p / 4 + jitter).max(1);
    }
    p.min(max_procs)
}

/// Runtime: log-uniform-ish long tail, 30 s .. 60 h, median ~15 min
/// (KTH-SP2's cleaned runtimes are minutes-heavy with a multi-hour tail).
fn sample_runtime(rng: &mut Pcg32) -> Duration {
    let ln = rng.normal_ms((900.0f64).ln(), 1.9);
    Duration::from_secs_f64(ln.exp().clamp(30.0, 60.0 * 3600.0))
}

/// User walltime estimate: notoriously loose. 15% near-exact, the rest a
/// log-normal multiple (median 2x), floored at 1.25x. On top of the
/// compute estimate, users (and the paper's Batsim profiles) budget for
/// the data-staging phases: we add an I/O headroom proportional to the
/// bytes each Fig-4 stage moves (stage-in + (phases-1) checkpoints +
/// stage-out) at a conservative quarter of a 10 Gbit/s uplink, so jobs
/// are not mass-killed by ordinary I/O stretching while heavily
/// contended jobs can still exceed their walltime (as in reality).
fn sample_walltime(rng: &mut Pcg32, runtime: Duration, bb: u64, phases: u32) -> Duration {
    let factor = if rng.bool(0.15) {
        1.3
    } else {
        rng.lognormal((2.0f64).ln(), 0.8).clamp(1.25, 20.0)
    };
    (runtime.mul_f64(factor) + io_headroom(bb, phases)).min(Duration::from_secs(120 * 3600))
}

/// The I/O headroom users (and the paper's Batsim profiles) budget on
/// top of a compute estimate: time for the bytes each Fig-4 stage moves
/// (stage-in + (phases-1) checkpoints + stage-out) at a conservative
/// quarter of a 10 Gbit/s uplink. Shared with the scenario engine's
/// walltime-estimate models so every estimate family keeps jobs
/// survivable under ordinary I/O stretching.
pub fn io_headroom(bb: u64, phases: u32) -> Duration {
    let stages = (phases + 1) as f64; // stage-in + checkpoints + stage-out
    Duration::from_secs_f64(stages * bb as f64 / (1.25e9 / 4.0))
}

/// Generate the synthetic trace (sorted by submit time).
pub fn generate(cfg: &SynthConfig) -> Vec<Job> {
    let mut rng = Pcg32::seeded(cfg.seed);
    let span_hours = cfg.span_weeks * 168.0;
    // Thinning-free approach: accumulate interarrivals scaled by the
    // inverse intensity at the current time-of-week.
    let mean_intensity = 0.649; // integral of week_intensity over a week / 168
    // jobs/s at intensity 1
    let base_rate = cfg.n_jobs as f64 / (span_hours * 3600.0) / mean_intensity;
    let max_bb_total = (cfg.bb_capacity as f64 * cfg.max_bb_capacity_fraction) as u64;

    let mut jobs = Vec::with_capacity(cfg.n_jobs);
    let mut t = 0.0f64; // seconds
    while jobs.len() < cfg.n_jobs {
        let how = (t / 3600.0) % 168.0;
        let rate = base_rate * week_intensity(how).max(0.05);
        t += rng.exponential(rate);
        // Bursts: 10% of arrivals bring a batch of 2-6 near-simultaneous
        // submissions (campaigns are common in real logs).
        let burst = if rng.bool(0.1) { rng.range_u32(2, 6) } else { 1 };
        for _ in 0..burst {
            if jobs.len() >= cfg.n_jobs {
                break;
            }
            let submit = Time::from_secs_f64(t + rng.range_f64(0.0, 2.0));
            let procs = sample_procs(&mut rng, cfg.max_procs);
            let runtime = sample_runtime(&mut rng);
            let bb = cfg.bb_model.sample(&mut rng, procs, max_bb_total).max(1);
            let phases = 1 + rng.below(10);
            let walltime = sample_walltime(&mut rng, runtime, bb, phases);
            jobs.push(Job {
                id: JobId(jobs.len() as u32),
                submit,
                walltime,
                compute_time: runtime,
                procs,
                bb, // every job uses the burst buffer (paper §3.2)
                phases,
            });
        }
    }
    jobs.sort_by_key(|j| j.submit);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = JobId(i as u32);
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::resources::GIB;

    #[test]
    fn generates_requested_count_sorted() {
        let cfg = SynthConfig::scaled(1, 0.02);
        let jobs = generate(&cfg);
        assert_eq!(jobs.len(), cfg.n_jobs);
        for w in jobs.windows(2) {
            assert!(w[0].submit <= w[1].submit);
        }
        for j in &jobs {
            j.validate().unwrap();
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&SynthConfig::scaled(42, 0.01));
        let b = generate(&SynthConfig::scaled(42, 0.01));
        assert_eq!(a, b);
        let c = generate(&SynthConfig::scaled(43, 0.01));
        assert_ne!(a, c);
    }

    #[test]
    fn span_roughly_matches() {
        let cfg = SynthConfig::scaled(7, 0.05);
        let jobs = generate(&cfg);
        let span_h = jobs.last().unwrap().submit.as_hours_f64();
        let want = cfg.span_weeks * 168.0;
        assert!(span_h > want * 0.6 && span_h < want * 1.6, "span {span_h}h want ~{want}h");
    }

    #[test]
    fn marginals_in_expected_ranges() {
        let cfg = SynthConfig::scaled(11, 0.1);
        let jobs = generate(&cfg);
        let n = jobs.len() as f64;
        // Processors: small-job dominated, clamped.
        let mean_procs: f64 = jobs.iter().map(|j| j.procs as f64).sum::<f64>() / n;
        assert!((2.0..16.0).contains(&mean_procs), "mean procs {mean_procs}");
        assert!(jobs.iter().all(|j| j.procs >= 1 && j.procs <= 96));
        // Runtime median in minutes-to-an-hour territory.
        let mut rt: Vec<f64> = jobs.iter().map(|j| j.compute_time.as_secs_f64()).collect();
        rt.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = rt[rt.len() / 2];
        assert!((120.0..7200.0).contains(&med), "median runtime {med}");
        // Walltime strictly above runtime.
        assert!(jobs.iter().all(|j| j.walltime > j.compute_time));
        // Everyone asks for burst buffer; totals within the cap.
        let cap = (cfg.bb_capacity as f64 * cfg.max_bb_capacity_fraction) as u64;
        assert!(jobs.iter().all(|j| j.bb >= 1 && j.bb <= cap));
        // Mean per-proc request within 3x of the model mean (clamps skew it).
        let mean_pp: f64 =
            jobs.iter().map(|j| j.bb as f64 / j.procs as f64).sum::<f64>() / n / GIB as f64;
        assert!((0.5..12.0).contains(&mean_pp), "mean bb/proc {mean_pp} GiB");
    }

    #[test]
    fn weekday_days_busier_than_weekends() {
        let cfg = SynthConfig::scaled(13, 0.2);
        let jobs = generate(&cfg);
        let (mut weekday, mut weekend) = (0u32, 0u32);
        for j in &jobs {
            let how = (j.submit.as_secs_f64() / 3600.0) % 168.0;
            if (how / 24.0) as usize >= 5 {
                weekend += 1;
            } else {
                weekday += 1;
            }
        }
        // Per-day rates: weekday avg should clearly exceed weekend avg.
        let wd_rate = weekday as f64 / 5.0;
        let we_rate = weekend as f64 / 2.0;
        assert!(wd_rate > we_rate * 1.5, "weekday {wd_rate} vs weekend {we_rate}");
    }

    #[test]
    fn intensity_function_shape() {
        assert!(week_intensity(10.0) > week_intensity(3.0)); // office hours > night
        assert!(week_intensity(10.0) > week_intensity(5.0 * 24.0 + 10.0)); // Mon > Sat
    }
}
