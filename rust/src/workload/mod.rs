//! Workload models: SWF log ingestion, the KTH-SP2 statistical twin
//! generator, the log-normal burst-buffer request model, and the
//! 16-part splitter for the robustness figures.

pub mod bbmodel;
pub mod split;
pub mod swf;
pub mod synth;

pub use bbmodel::BbModel;
pub use split::split_workload;
pub use swf::{parse_swf, records_to_jobs, SwfConvert, SwfRecord};
pub use synth::{generate, SynthConfig};
