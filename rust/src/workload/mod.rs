//! Workload models: SWF log ingestion, the KTH-SP2 statistical twin
//! generator, the log-normal burst-buffer request model, the 16-part
//! splitter for the robustness figures — and the composable scenario
//! engine ([`scenario`]) that turns them into a swept scenario space
//! (workload families x estimate models x burst-buffer architectures).

pub mod bbmodel;
pub mod scenario;
pub mod split;
pub mod swf;
pub mod synth;

pub use bbmodel::BbModel;
pub use scenario::{EstimateModel, Family, Scenario, WorkloadSpec};
pub use split::split_workload;
pub use swf::{parse_swf, records_to_jobs, SwfConvert, SwfRecord};
pub use synth::{generate, SynthConfig};

use crate::core::job::Job;
use crate::platform::{PlatformSpec, TopologyConfig};

/// Materialise a workload on a platform: the jobs plus the burst-buffer
/// capacity the simulator must be configured with. Thin wrapper over
/// [`Scenario::materialise`] for callers that hold the two halves
/// separately (the CLI and the campaign runner). The CLI sizes for the
/// paper's default machine; `materialise` itself takes the topology
/// explicitly.
pub fn load_scenario(
    workload: &WorkloadSpec,
    platform: &PlatformSpec,
    seed: u64,
) -> Result<(Vec<Job>, u64), String> {
    Scenario { workload: workload.clone(), platform: *platform }
        .materialise(seed, &TopologyConfig::default())
}
