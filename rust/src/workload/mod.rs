//! Workload models: SWF log ingestion, the KTH-SP2 statistical twin
//! generator, the log-normal burst-buffer request model, and the
//! 16-part splitter for the robustness figures.

pub mod bbmodel;
pub mod split;
pub mod swf;
pub mod synth;

pub use bbmodel::BbModel;
pub use split::split_workload;
pub use swf::{parse_swf, records_to_jobs, SwfConvert, SwfRecord};
pub use synth::{generate, SynthConfig};

use crate::core::job::Job;
use std::path::PathBuf;

/// Where one run's jobs come from — the unit the campaign grid sweeps.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSource {
    /// The KTH-SP2 statistical twin at a fraction of the paper's size
    /// (`scale = 1.0` is the full 28,453-job trace).
    Synth { scale: f64 },
    /// A real SWF trace, converted with the paper's §4.1 supplement rules.
    Swf { path: PathBuf },
}

impl WorkloadSource {
    /// Short label used in run names and progress lines.
    pub fn label(&self) -> String {
        match self {
            WorkloadSource::Synth { scale } => format!("x{scale}"),
            WorkloadSource::Swf { path } => path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "swf".to_string()),
        }
    }
}

/// Materialise a workload: the jobs plus the burst-buffer capacity the
/// simulator must be configured with. `bb_factor` scales the paper's
/// capacity rule (capacity = expected demand at full load); the
/// METACENTRUM fit the paper used is unpublished, so EXPERIMENTS.md
/// sweeps this factor. Shared by the CLI and the campaign runner.
pub fn load_source(
    source: &WorkloadSource,
    seed: u64,
    bb_factor: f64,
) -> Result<(Vec<Job>, u64), String> {
    match source {
        WorkloadSource::Swf { path } => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading SWF file {}: {e}", path.display()))?;
            let (records, skipped) = parse_swf(&text);
            if skipped > 0 {
                eprintln!("note: skipped {skipped} malformed SWF lines");
            }
            let bb_model = BbModel::default();
            let bb_capacity = (bb_model.capacity_for(96) as f64 * bb_factor) as u64;
            let jobs = records_to_jobs(
                &records,
                &SwfConvert {
                    max_procs: 96,
                    walltime_factor_min: 1.25,
                    max_bb_total: (bb_capacity as f64 * 0.8) as u64,
                    bb_model,
                    seed,
                },
            );
            Ok((jobs, bb_capacity))
        }
        WorkloadSource::Synth { scale } => {
            if !scale.is_finite() || *scale <= 0.0 {
                return Err(format!("synthetic workload scale must be positive, got {scale}"));
            }
            let mut cfg = if (scale - 1.0).abs() < 1e-9 {
                SynthConfig::paper(seed)
            } else {
                SynthConfig::scaled(seed, *scale)
            };
            cfg.bb_capacity = (cfg.bb_capacity as f64 * bb_factor) as u64;
            let jobs = generate(&cfg);
            Ok((jobs, cfg.bb_capacity))
        }
    }
}
