//! The composable scenario engine: workload families x estimate models
//! x burst-buffer architectures.
//!
//! The paper's conclusions rest on a single statistical twin of KTH-SP2
//! with one log-normal burst-buffer model and one shared-pool
//! architecture. Related work (Kopanski's thesis, arXiv 2111.10200;
//! "Scheduling Beyond CPUs for HPC", arXiv 2012.05439) shows scheduler
//! rankings shift with I/O intensity, walltime-estimate accuracy and
//! multi-resource sizing — so every robustness claim this repository
//! makes runs over a *scenario space* instead of the single hard-coded
//! experiment:
//!
//! - [`Family`]: how jobs are generated — the paper twin, bursty arrival
//!   storms, I/O-intensity mixes, heavy-tailed burst-buffer variants, or
//!   SWF replay with scaling/filtering knobs.
//! - [`EstimateModel`]: how loose user walltime estimates are, from the
//!   twin's calibrated looseness through near-exact to x10-sloppy.
//! - [`crate::platform::PlatformSpec`]: the platform half — burst-buffer
//!   architecture ([`crate::platform::BbArch`]: the paper's shared
//!   pool, real per-node *placement* where the allocator can fragment,
//!   or the legacy per-node request clamp) and the capacity sizing
//!   factor.
//!
//! A [`Scenario`] is one point in that space; [`Scenario::materialise`]
//! turns it into (jobs, burst-buffer capacity) deterministically from a
//! seed. One fixed rule keeps the axes orthogonal: the burst-buffer
//! *capacity* always comes from the paper's rule (default model's
//! expected demand at full load) times `bb_factor` — families change
//! demand, the platform changes supply, and neither silently rescales
//! the other.

use crate::core::job::Job;
use crate::core::time::{Duration, Time};
use crate::platform::topology::{Topology, TopologyConfig};
use crate::platform::{BbArch, BurstBufferPool, NodeRole, PlatformSpec};
use crate::stats::rng::Pcg32;
use crate::workload::bbmodel::BbModel;
use crate::workload::swf::{parse_swf, records_to_jobs, SwfConvert};
use crate::workload::synth::{generate, io_headroom, SynthConfig};
use std::path::PathBuf;

/// Default arrival-storm compression (arrivals land 4x closer to their
/// window start than in the twin).
pub const DEFAULT_STORM_INTENSITY: f64 = 4.0;
/// Default I/O-mix multiplier on every job's burst-buffer request.
pub const DEFAULT_IO_MIX_FACTOR: f64 = 3.0;
/// Default ln-space sigma for the heavy-tailed burst-buffer variant
/// (the paper's model uses 1.0).
pub const DEFAULT_HEAVY_TAIL_SIGMA: f64 = 1.6;

/// Storm window: arrivals are compressed toward the start of 6-hour
/// windows, creating periodic submission storms (campaign behaviour).
const STORM_WINDOW_S: f64 = 6.0 * 3600.0;

/// Walltime cap shared with the synthetic twin (5 days).
const MAX_WALLTIME_S: u64 = 120 * 3600;

/// How one scenario's jobs are generated.
#[derive(Debug, Clone, PartialEq)]
pub enum Family {
    /// The KTH-SP2 statistical twin exactly as the paper uses it.
    PaperTwin,
    /// The twin with arrivals compressed toward 6-hour window starts:
    /// `intensity` = how much closer to the window start each arrival
    /// lands (1.0 = the twin; 4.0 = 4x compression). Queue depth spikes
    /// periodically, stressing backfill depth and plan length.
    ArrivalStorm { intensity: f64 },
    /// The twin with every burst-buffer request multiplied by `factor`
    /// (clamped to the schedulable maximum). Walltime estimates are NOT
    /// rescaled, so `factor > 1` also models under-budgeted staging
    /// time — the I/O-pressure regime where BB-aware reservations
    /// matter most; `factor < 1` de-intensifies I/O.
    IoMix { factor: f64 },
    /// The twin with the burst-buffer request model's ln-space sigma
    /// replaced by `sigma` (paper: 1.0): a heavier per-job tail under
    /// the *paper's* capacity, so a few whales dominate the pool.
    HeavyTailBb { sigma: f64 },
    /// Replay a real SWF trace (scale < 1 keeps the first fraction of
    /// jobs — the filtering knob).
    SwfReplay { path: PathBuf },
}

impl Family {
    /// Parse a spec token: `paper`, `storm[:K]`, `io-mix[:K]`,
    /// `heavy-tail[:S]`, `swf:PATH`.
    pub fn parse(s: &str) -> Result<Family, String> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (s.trim(), None),
        };
        let num = |what: &str, default: f64, min: f64| -> Result<f64, String> {
            match arg {
                None => Ok(default),
                Some(a) => {
                    let v: f64 = a
                        .parse()
                        .map_err(|_| format!("invalid {what} `{a}` in family `{s}`"))?;
                    if !v.is_finite() || v < min || (min == 0.0 && v == 0.0) {
                        let bound =
                            if min == 0.0 { "positive".to_string() } else { format!(">= {min}") };
                        return Err(format!("{what} must be {bound}, got `{a}`"));
                    }
                    Ok(v)
                }
            }
        };
        match name {
            "paper" => {
                if arg.is_some() {
                    return Err(format!("family `paper` takes no parameter (got `{s}`)"));
                }
                Ok(Family::PaperTwin)
            }
            "storm" => Ok(Family::ArrivalStorm {
                intensity: num("storm intensity", DEFAULT_STORM_INTENSITY, 1.0)?,
            }),
            "io-mix" | "iomix" => Ok(Family::IoMix {
                factor: num("io-mix factor", DEFAULT_IO_MIX_FACTOR, 0.0)?,
            }),
            "heavy-tail" | "heavytail" => Ok(Family::HeavyTailBb {
                sigma: num("heavy-tail sigma", DEFAULT_HEAVY_TAIL_SIGMA, 0.0)?,
            }),
            "swf" => match arg {
                Some(path) if !path.is_empty() => {
                    Ok(Family::SwfReplay { path: PathBuf::from(path) })
                }
                _ => Err("family `swf` needs a path: `swf:traces/kth.swf`".to_string()),
            },
            other => Err(format!(
                "unknown workload family `{other}` (paper|storm[:K]|io-mix[:K]|heavy-tail[:S]|swf:PATH)"
            )),
        }
    }

    /// Canonical spec token (round-trips through [`Family::parse`]).
    pub fn spec_token(&self) -> String {
        match self {
            Family::PaperTwin => "paper".to_string(),
            Family::ArrivalStorm { intensity } => format!("storm:{intensity}"),
            Family::IoMix { factor } => format!("io-mix:{factor}"),
            Family::HeavyTailBb { sigma } => format!("heavy-tail:{sigma}"),
            Family::SwfReplay { path } => format!("swf:{}", path.display()),
        }
    }

    /// Short label fragment ("" for the paper twin, so paper-faithful
    /// run labels are byte-identical to the pre-scenario format).
    fn label_fragment(&self) -> String {
        match self {
            Family::PaperTwin => String::new(),
            Family::ArrivalStorm { intensity } => format!("storm{intensity}-"),
            Family::IoMix { factor } => format!("iomix{factor}-"),
            Family::HeavyTailBb { sigma } => format!("ht{sigma}-"),
            Family::SwfReplay { .. } => String::new(),
        }
    }
}

/// How loose the user walltime estimates are.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EstimateModel {
    /// Whatever the family generates (the twin's calibrated looseness:
    /// 15% near-exact, log-normal median 2x otherwise).
    Paper,
    /// Near-exact estimates: walltime = 1.05 x compute time plus the
    /// I/O headroom for the job's actual request. The regime where
    /// backfilling has perfect information.
    Exact,
    /// Sloppy estimates: per-job log-normal factor with median `factor`
    /// (sigma 0.8, clamped to [1.25, 10 x factor]) plus I/O headroom.
    /// `x10` models the worst published estimate quality.
    Sloppy { factor: f64 },
}

impl EstimateModel {
    /// Parse a spec token: `paper`, `exact`, or `xK` (e.g. `x4`, `x10`).
    pub fn parse(s: &str) -> Result<EstimateModel, String> {
        match s {
            "paper" => Ok(EstimateModel::Paper),
            "exact" => Ok(EstimateModel::Exact),
            _ => {
                let Some(rest) = s.strip_prefix('x') else {
                    return Err(format!("unknown estimate model `{s}` (paper|exact|xK)"));
                };
                let factor: f64 = rest
                    .parse()
                    .map_err(|_| format!("invalid estimate factor in `{s}`"))?;
                if !factor.is_finite() || factor < 1.0 {
                    return Err(format!("estimate factor must be >= 1, got `{s}`"));
                }
                Ok(EstimateModel::Sloppy { factor })
            }
        }
    }

    /// Canonical spec token (round-trips through [`EstimateModel::parse`]).
    pub fn spec_token(&self) -> String {
        match self {
            EstimateModel::Paper => "paper".to_string(),
            EstimateModel::Exact => "exact".to_string(),
            EstimateModel::Sloppy { factor } => format!("x{factor}"),
        }
    }

    /// Label suffix ("" for the paper model).
    fn label_suffix(&self) -> String {
        match self {
            EstimateModel::Paper => String::new(),
            EstimateModel::Exact => "-exact".to_string(),
            EstimateModel::Sloppy { factor } => format!("-estx{factor}"),
        }
    }
}

/// The workload half of a scenario: family x size x estimate quality.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub family: Family,
    /// Fraction of the paper-scale trace (1.0 = 28,453 jobs / 48 weeks
    /// for synthetic families; for SWF replay, the kept job fraction).
    pub scale: f64,
    pub estimate: EstimateModel,
}

impl WorkloadSpec {
    /// The paper's workload at a fraction of its size (the pre-scenario
    /// `Synth { scale }` source).
    pub fn paper_twin(scale: f64) -> WorkloadSpec {
        WorkloadSpec { family: Family::PaperTwin, scale, estimate: EstimateModel::Paper }
    }

    /// A real SWF trace, converted with the paper's §4.1 supplement
    /// rules (the pre-scenario `Swf { path }` source).
    pub fn swf(path: PathBuf) -> WorkloadSpec {
        WorkloadSpec {
            family: Family::SwfReplay { path },
            scale: 1.0,
            estimate: EstimateModel::Paper,
        }
    }

    /// Short label used in run names and progress lines. Paper-twin
    /// specs keep the pre-scenario `x{scale}` form.
    pub fn label(&self) -> String {
        let base = match &self.family {
            Family::SwfReplay { path } => {
                let stem = path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "swf".to_string());
                if (self.scale - 1.0).abs() < 1e-9 {
                    stem
                } else {
                    format!("{stem}-x{}", self.scale)
                }
            }
            fam => format!("{}x{}", fam.label_fragment(), self.scale),
        };
        format!("{base}{}", self.estimate.label_suffix())
    }
}

/// One point of the scenario space: a workload on a platform.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub workload: WorkloadSpec,
    pub platform: PlatformSpec,
}

impl Scenario {
    /// Scenario identity label (workload + architecture + sizing) — the
    /// grouping key for per-scenario aggregation across seeds/policies.
    pub fn label(&self) -> String {
        format!(
            "{}{}+bb{}",
            self.workload.label(),
            self.platform.bb_arch.label_segment(),
            self.platform.bb_factor
        )
    }

    /// Materialise the scenario on an explicit topology: the job list
    /// plus the burst-buffer capacity the simulator must be configured
    /// with. Deterministic in `(seed, topo)`; shared by the CLI, the
    /// campaign runner and the serve session layer. The compute-node
    /// count (the capacity rule's full-load processor count and the
    /// per-node clamp divisor) and the per-group storage capacities
    /// (the per-node placement clamp) are derived from `topo` — there
    /// is deliberately no defaulted form, so every caller states whose
    /// machine the workload is sized for.
    pub fn materialise(
        &self,
        seed: u64,
        topo: &TopologyConfig,
    ) -> Result<(Vec<Job>, u64), String> {
        let scale = self.workload.scale;
        if !scale.is_finite() || scale <= 0.0 {
            return Err(format!("workload scale must be positive, got {scale}"));
        }
        let bb_factor = self.platform.bb_factor;
        if !bb_factor.is_finite() || bb_factor <= 0.0 {
            return Err(format!("bb-factor must be positive, got {bb_factor}"));
        }
        let machine = Topology::build(topo.clone());
        let n_compute = machine.n_compute() as u32;
        if n_compute == 0 {
            return Err("topology has no compute nodes".to_string());
        }
        // The one capacity rule (see module docs): the paper's default
        // model's expected demand at full load, scaled by the platform.
        let default_model = BbModel::default();
        let bb_capacity = (default_model.capacity_for(n_compute) as f64 * bb_factor) as u64;
        let max_bb_total = (bb_capacity as f64 * 0.8) as u64;

        let mut jobs = match &self.workload.family {
            Family::SwfReplay { path } => {
                // Replay cannot upscale: scale > 1 would silently
                // duplicate the 1.0 cell under a distinct label.
                if scale > 1.0 {
                    return Err(format!(
                        "SWF replay scale must be <= 1 (kept job fraction), got {scale}"
                    ));
                }
                let text = std::fs::read_to_string(path)
                    .map_err(|e| format!("reading SWF file {}: {e}", path.display()))?;
                let (records, skipped) = parse_swf(&text);
                if skipped > 0 {
                    eprintln!("note: skipped {skipped} malformed SWF lines");
                }
                let mut jobs = records_to_jobs(
                    &records,
                    &SwfConvert {
                        max_procs: n_compute,
                        walltime_factor_min: 1.25,
                        max_bb_total,
                        bb_model: default_model,
                        seed,
                    },
                );
                if scale < 1.0 {
                    let keep = ((jobs.len() as f64 * scale).ceil() as usize).max(1);
                    jobs.truncate(keep);
                }
                jobs
            }
            family => {
                let mut cfg = if (scale - 1.0).abs() < 1e-9 {
                    SynthConfig::paper(seed)
                } else {
                    SynthConfig::scaled(seed, scale)
                };
                cfg.bb_capacity = bb_capacity;
                cfg.max_procs = n_compute;
                if let Family::HeavyTailBb { sigma } = family {
                    cfg.bb_model.lognorm.sigma = *sigma;
                }
                let mut jobs = generate(&cfg);
                match family {
                    Family::ArrivalStorm { intensity } => {
                        compress_arrivals(&mut jobs, *intensity);
                    }
                    Family::IoMix { factor } => scale_bb(&mut jobs, *factor, max_bb_total),
                    _ => {}
                }
                jobs
            }
        };

        // Platform clamps before the estimate transform so walltime
        // headroom reflects the request the job actually gets.
        match self.platform.bb_arch {
            BbArch::Shared => {}
            // Real per-node placement: jobs keep their full requests up
            // to the schedulability bound — the smallest single group's
            // storage capacity (a bigger request could be forever
            // unplaceable once best-fit sends its compute there; the
            // simulator rejects such workloads loudly). Contention and
            // fragmentation then play out in the allocator.
            BbArch::PerNode => {
                let storage: Vec<(usize, usize)> = machine
                    .nodes
                    .iter()
                    .filter(|n| n.role == NodeRole::Storage)
                    .map(|n| (n.id, n.group))
                    .collect();
                let min_group =
                    BurstBufferPool::new(&storage, bb_capacity).min_group_capacity();
                clamp_to(&mut jobs, min_group);
            }
            // Legacy approximation: clamp the request at `procs x
            // per-node capacity` with the per-node capacity derived
            // from the *topology's* compute-node count (pre-PR this
            // hard-coded the paper's 96, silently mis-clamping any
            // other machine shape).
            BbArch::PerNodeClamp => clamp_per_node(&mut jobs, bb_capacity, n_compute),
        }
        apply_estimate(&mut jobs, self.workload.estimate, seed);

        // Transforms may have reordered arrivals; restore the sorted,
        // densely-id'd canonical form every consumer assumes.
        jobs.sort_by_key(|j| (j.submit, j.id.0));
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = crate::core::job::JobId(i as u32);
            j.validate().map_err(|e| format!("scenario produced invalid job: {e}"))?;
        }
        Ok((jobs, bb_capacity))
    }
}

/// Compress each arrival toward the start of its 6-hour window by
/// `intensity`, creating periodic submission storms.
fn compress_arrivals(jobs: &mut [Job], intensity: f64) {
    debug_assert!(intensity >= 1.0);
    for j in jobs.iter_mut() {
        let t = j.submit.as_secs_f64();
        let w = (t / STORM_WINDOW_S).floor() * STORM_WINDOW_S;
        j.submit = Time::from_secs_f64(w + (t - w) / intensity);
    }
}

/// Multiply every burst-buffer request, clamped to the schedulable
/// maximum (so every job stays launchable).
fn scale_bb(jobs: &mut [Job], factor: f64, max_bb_total: u64) {
    for j in jobs.iter_mut() {
        j.bb = (((j.bb as f64) * factor) as u64).clamp(1, max_bb_total);
    }
}

/// Legacy per-node approximation: a job can only use the node-local
/// buffers of its own allocation, so its usable request caps at
/// `procs x (capacity / compute nodes)` — a generator-side transform
/// that leaves the platform shared (no fragmentation possible).
fn clamp_per_node(jobs: &mut [Job], bb_capacity: u64, n_compute: u32) {
    let per_node = bb_capacity / n_compute as u64;
    for j in jobs.iter_mut() {
        j.bb = j.bb.min(j.procs as u64 * per_node).max(1);
    }
}

/// Per-node placement schedulability clamp: cap every request at the
/// smallest single storage group's capacity.
fn clamp_to(jobs: &mut [Job], max_bb: u64) {
    for j in jobs.iter_mut() {
        j.bb = j.bb.min(max_bb).max(1);
    }
}

/// Re-derive walltime estimates under the chosen model. `Paper` leaves
/// the family's estimates untouched.
fn apply_estimate(jobs: &mut [Job], est: EstimateModel, seed: u64) {
    let cap = Duration::from_secs(MAX_WALLTIME_S);
    match est {
        EstimateModel::Paper => {}
        EstimateModel::Exact => {
            for j in jobs.iter_mut() {
                j.walltime =
                    (j.compute_time.mul_f64(1.05) + io_headroom(j.bb, j.phases)).min(cap);
            }
        }
        EstimateModel::Sloppy { factor } => {
            // A dedicated stream so estimate noise never perturbs the
            // family's own generation stream.
            let mut rng = Pcg32::new(seed, 0xe571_0a7e_57a7_e5ed);
            for j in jobs.iter_mut() {
                let f = rng.lognormal(factor.ln(), 0.8).clamp(1.25, factor * 10.0);
                j.walltime = (j.compute_time.mul_f64(f) + io_headroom(j.bb, j.phases)).min(cap);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::resources::GIB;

    fn scenario(family: Family, scale: f64) -> Scenario {
        Scenario {
            workload: WorkloadSpec { family, scale, estimate: EstimateModel::Paper },
            platform: PlatformSpec::default(),
        }
    }

    /// The paper's default machine — materialise now always takes the
    /// topology explicitly, so the tests name their choice once here.
    fn topo() -> TopologyConfig {
        TopologyConfig::default()
    }

    #[test]
    fn family_tokens_round_trip() {
        let fams = [
            Family::PaperTwin,
            Family::ArrivalStorm { intensity: 4.0 },
            Family::IoMix { factor: 0.25 },
            Family::HeavyTailBb { sigma: 1.6 },
            Family::SwfReplay { path: PathBuf::from("traces/kth.swf") },
        ];
        for f in fams {
            assert_eq!(Family::parse(&f.spec_token()), Ok(f.clone()), "{f:?}");
        }
        // Defaults fill in without an argument.
        assert_eq!(
            Family::parse("storm"),
            Ok(Family::ArrivalStorm { intensity: DEFAULT_STORM_INTENSITY })
        );
        assert_eq!(
            Family::parse("heavy-tail"),
            Ok(Family::HeavyTailBb { sigma: DEFAULT_HEAVY_TAIL_SIGMA })
        );
        assert!(Family::parse("paper:2").is_err());
        assert!(Family::parse("storm:0.5").is_err()); // < 1 would stretch
        assert!(Family::parse("swf").is_err());
        assert!(Family::parse("warp").is_err());
    }

    #[test]
    fn estimate_tokens_round_trip() {
        let models =
            [EstimateModel::Paper, EstimateModel::Exact, EstimateModel::Sloppy { factor: 10.0 }];
        for e in models {
            assert_eq!(EstimateModel::parse(&e.spec_token()), Ok(e));
        }
        assert!(EstimateModel::parse("x0.5").is_err());
        assert!(EstimateModel::parse("sharp").is_err());
    }

    #[test]
    fn paper_twin_matches_the_legacy_pipeline_bit_for_bit() {
        // The scenario engine must not perturb the paper-faithful path:
        // same jobs and capacity as driving the generator directly.
        let (jobs, cap) = scenario(Family::PaperTwin, 0.003).materialise(1, &topo()).unwrap();
        let cfg = SynthConfig::scaled(1, 0.003);
        assert_eq!(cap, cfg.bb_capacity);
        assert_eq!(jobs, generate(&cfg));
    }

    #[test]
    fn labels_are_stable_and_paper_compatible() {
        assert_eq!(WorkloadSpec::paper_twin(0.003).label(), "x0.003");
        let w = WorkloadSpec {
            family: Family::ArrivalStorm { intensity: 4.0 },
            scale: 0.01,
            estimate: EstimateModel::Sloppy { factor: 10.0 },
        };
        assert_eq!(w.label(), "storm4-x0.01-estx10");
        let s = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::PerNode, bb_factor: 0.5 },
        };
        assert_eq!(s.label(), "x0.01+pernode+bb0.5");
        let c = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::PerNodeClamp, bb_factor: 1.0 },
        };
        assert_eq!(c.label(), "x0.01+pnclamp+bb1");
    }

    #[test]
    fn storm_compresses_arrivals_into_windows() {
        let (base, _) = scenario(Family::PaperTwin, 0.01).materialise(3, &topo()).unwrap();
        let (storm, _) =
            scenario(Family::ArrivalStorm { intensity: 4.0 }, 0.01)
                .materialise(3, &topo())
                .unwrap();
        assert_eq!(base.len(), storm.len());
        // Every storm arrival sits in the first quarter of its window.
        for j in &storm {
            let t = j.submit.as_secs_f64();
            let off = t - (t / STORM_WINDOW_S).floor() * STORM_WINDOW_S;
            assert!(off <= STORM_WINDOW_S / 4.0 + 1e-6, "offset {off}");
        }
        // Same total span order of magnitude (compression is within
        // windows, not global).
        let span = |js: &[Job]| js.last().unwrap().submit.as_secs_f64();
        assert!(span(&storm) >= span(&base) * 0.8);
    }

    #[test]
    fn io_mix_scales_requests_within_clamp() {
        let (base, cap) = scenario(Family::PaperTwin, 0.01).materialise(5, &topo()).unwrap();
        let (mix, _) =
            scenario(Family::IoMix { factor: 3.0 }, 0.01).materialise(5, &topo()).unwrap();
        let max_total = (cap as f64 * 0.8) as u64;
        let sum = |js: &[Job]| js.iter().map(|j| j.bb as u128).sum::<u128>();
        assert!(sum(&mix) > sum(&base), "io-mix must increase aggregate demand");
        assert!(mix.iter().all(|j| j.bb >= 1 && j.bb <= max_total));
        // De-intensifying shrinks demand.
        let (lean, _) =
            scenario(Family::IoMix { factor: 0.25 }, 0.01).materialise(5, &topo()).unwrap();
        assert!(sum(&lean) < sum(&base));
    }

    #[test]
    fn heavy_tail_fattens_the_upper_quantiles() {
        let (base, _) = scenario(Family::PaperTwin, 0.02).materialise(7, &topo()).unwrap();
        let (ht, _) =
            scenario(Family::HeavyTailBb { sigma: 1.8 }, 0.02).materialise(7, &topo()).unwrap();
        let q90 = |js: &[Job]| {
            let mut v: Vec<u64> = js.iter().map(|j| j.bb / j.procs as u64).collect();
            v.sort_unstable();
            v[(v.len() as f64 * 0.9) as usize] as f64 / GIB as f64
        };
        assert!(q90(&ht) > q90(&base), "ht q90 {} <= base q90 {}", q90(&ht), q90(&base));
    }

    #[test]
    fn per_node_clamp_arch_caps_requests_by_allocation() {
        let spec = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::PerNodeClamp, bb_factor: 1.0 },
        };
        let (jobs, cap) = spec.materialise(9, &topo()).unwrap();
        let per_node = cap / 96;
        for j in &jobs {
            let cap_j = j.procs as u64 * per_node;
            assert!(j.bb <= cap_j, "{}: {} > {}x{per_node}", j.id, j.bb, j.procs);
        }
        // The aggregate constraint can therefore never bind beyond the
        // node allocation: sum over any <=96-proc set fits capacity.
        assert!(jobs.iter().all(|j| j.bb <= cap));
    }

    #[test]
    fn per_node_placement_arch_clamps_to_the_smallest_group_only() {
        // The placement arch keeps full requests up to the smallest
        // single group's storage capacity (the schedulability bound) —
        // NOT the legacy `procs x per-node` clamp, so per-node runs
        // exercise genuine group contention.
        let per_node = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::PerNode, bb_factor: 1.0 },
        };
        let (jobs, cap) = per_node.materialise(9, &topo()).unwrap();
        // Default topology: 12 storage nodes in 3 groups of 4.
        let min_group = {
            let base = cap / 12;
            let rem = cap % 12;
            4 * base + rem.saturating_sub(8)
        };
        assert!(jobs.iter().all(|j| j.bb >= 1 && j.bb <= min_group));
        // Some jobs genuinely exceed the legacy clamp (otherwise the
        // two archs would be indistinguishable).
        let legacy = |j: &Job| j.procs as u64 * (cap / 96);
        assert!(
            jobs.iter().any(|j| j.bb > legacy(j)),
            "per-node placement must keep requests the clamp would cut"
        );
        // And the two archs materialise different workloads.
        let clamped = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::PerNodeClamp, bb_factor: 1.0 },
        };
        assert_ne!(jobs, clamped.materialise(9, &topo()).unwrap().0);
    }

    #[test]
    fn clamp_divisor_follows_the_topology_not_the_paper_constant() {
        // A 12-compute-node machine (2 groups x 2 chassis x 1 router x
        // 4 node slots, 1 storage slot per chassis): the per-node clamp
        // must divide by 12, not the paper's 96.
        let topo = TopologyConfig {
            groups: 2,
            chassis_per_group: 2,
            routers_per_chassis: 1,
            nodes_per_router: 4,
            storage_per_chassis: 1,
            ..TopologyConfig::default()
        };
        let spec = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::PerNodeClamp, bb_factor: 1.0 },
        };
        let (jobs, cap) = spec.materialise(9, &topo).unwrap();
        let per_node = cap / 12;
        assert!(jobs.iter().all(|j| j.procs <= 12));
        assert!(jobs.iter().all(|j| j.bb <= j.procs as u64 * per_node));
        // The capacity rule also follows the machine size (12 procs at
        // full load, not 96) ...
        assert_eq!(cap, BbModel::default().capacity_for(12));
        // ... and the clamp is genuinely looser than a hard-coded 96
        // would make it: some job exceeds `procs x cap/96`.
        assert!(
            jobs.iter().any(|j| j.bb > j.procs as u64 * (cap / 96)),
            "clamp still divides by the paper's 96"
        );
    }

    #[test]
    fn estimate_models_reshape_walltimes() {
        let exact = Scenario {
            workload: WorkloadSpec {
                family: Family::PaperTwin,
                scale: 0.01,
                estimate: EstimateModel::Exact,
            },
            platform: PlatformSpec::default(),
        };
        let (jobs, _) = exact.materialise(11, &topo()).unwrap();
        for j in &jobs {
            assert!(j.walltime > j.compute_time);
            // Near-exact: within 5% + the I/O headroom.
            let slack = j.walltime.as_secs_f64()
                - j.compute_time.as_secs_f64() * 1.05
                - io_headroom(j.bb, j.phases).as_secs_f64();
            assert!(slack.abs() < 1.0, "slack {slack}");
        }
        let sloppy = Scenario {
            workload: WorkloadSpec {
                family: Family::PaperTwin,
                scale: 0.01,
                estimate: EstimateModel::Sloppy { factor: 10.0 },
            },
            platform: PlatformSpec::default(),
        };
        let (sj, _) = sloppy.materialise(11, &topo()).unwrap();
        let mean_factor = sj
            .iter()
            .map(|j| {
                (j.walltime.as_secs_f64() - io_headroom(j.bb, j.phases).as_secs_f64()).max(0.0)
                    / j.compute_time.as_secs_f64()
            })
            .sum::<f64>()
            / sj.len() as f64;
        // Median 10 with a 120 h cap: the mean factor must still be far
        // above the paper model's ~2.
        assert!(mean_factor > 4.0, "mean sloppy factor {mean_factor}");
    }

    #[test]
    fn materialise_is_deterministic_per_family() {
        let fams = [
            Family::PaperTwin,
            Family::ArrivalStorm { intensity: 4.0 },
            Family::IoMix { factor: 3.0 },
            Family::HeavyTailBb { sigma: 1.6 },
        ];
        for fam in fams {
            let a = scenario(fam.clone(), 0.005).materialise(42, &topo()).unwrap();
            let b = scenario(fam.clone(), 0.005).materialise(42, &topo()).unwrap();
            assert_eq!(a, b, "{fam:?}");
            let c = scenario(fam.clone(), 0.005).materialise(43, &topo()).unwrap();
            assert_ne!(a.0, c.0, "{fam:?} ignores the seed");
        }
    }

    #[test]
    fn invalid_parameters_error_cleanly() {
        assert!(scenario(Family::PaperTwin, 0.0).materialise(1, &topo()).is_err());
        assert!(scenario(Family::PaperTwin, f64::NAN).materialise(1, &topo()).is_err());
        let bad_platform = Scenario {
            workload: WorkloadSpec::paper_twin(0.01),
            platform: PlatformSpec { bb_arch: BbArch::Shared, bb_factor: 0.0 },
        };
        assert!(bad_platform.materialise(1, &topo()).is_err());
        let missing = scenario(Family::SwfReplay { path: PathBuf::from("/nope.swf") }, 1.0);
        assert!(missing.materialise(1, &topo()).unwrap_err().contains("reading SWF file"));
        // Replay upscaling would duplicate the x1 cell under a new
        // label; rejected before the file is even opened.
        let upscale = scenario(Family::SwfReplay { path: PathBuf::from("/nope.swf") }, 2.0);
        assert!(upscale.materialise(1, &topo()).unwrap_err().contains("must be <= 1"));
    }
}
