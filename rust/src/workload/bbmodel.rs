//! Burst-buffer request model (paper §4.1).
//!
//! PWA logs carry no burst-buffer requests, so the paper models the
//! request size per processor with a log-normal distribution fitted to
//! the METACENTRUM-2013-3 memory sizes (burst-buffer request == RAM
//! request being representative of checkpointing / data staging). That
//! raw log is not redistributable; we ship the fitted model family plus
//! the fitting pipeline (`stats::fit`) so any log can be re-fitted, and
//! default parameters that reproduce the paper's qualitative regime: a
//! long-tailed per-processor distribution whose *expected total request
//! at full machine load* defines the burst-buffer capacity.

use crate::core::resources::GIB;
use crate::stats::fit::LogNormal;
use crate::stats::rng::Pcg32;

/// Log-normal burst-buffer-per-processor model (bytes).
#[derive(Debug, Clone, Copy)]
pub struct BbModel {
    /// ln-space parameters over *GiB per processor*.
    pub lognorm: LogNormal,
    /// Per-processor clamp (bytes) keeping single requests physical.
    pub min_per_proc: u64,
    pub max_per_proc: u64,
}

impl Default for BbModel {
    /// Median 2 GiB/processor, sigma 1.0 — a long tail comparable to the
    /// METACENTRUM-2013-3 memory-request fit used in the paper
    /// (mean = 2 * e^0.5 ≈ 3.30 GiB/processor).
    fn default() -> BbModel {
        BbModel {
            lognorm: LogNormal { mu: (2.0f64).ln(), sigma: 1.0 },
            min_per_proc: GIB / 16, // 64 MiB
            max_per_proc: 64 * GIB,
        }
    }
}

impl BbModel {
    /// Fit from per-processor request samples in bytes (e.g. an SWF log's
    /// memory column). Returns `None` for insufficient data.
    pub fn fit_from_bytes(samples: &[f64]) -> Option<BbModel> {
        let gib: Vec<f64> = samples.iter().map(|b| b / GIB as f64).collect();
        Some(BbModel { lognorm: LogNormal::fit(&gib)?, ..BbModel::default() })
    }

    /// Expected request per processor in bytes.
    pub fn mean_per_proc(&self) -> u64 {
        (self.lognorm.mean() * GIB as f64) as u64
    }

    /// The paper's capacity rule: expected total request when every
    /// compute node is busy.
    pub fn capacity_for(&self, total_procs: u32) -> u64 {
        self.mean_per_proc() * total_procs as u64
    }

    /// Sample a job's total burst-buffer request. One per-processor draw
    /// scaled by the processor count (requests per processor are modelled
    /// independently of job size, as the paper found no cross-correlation
    /// for jobs under 64 processors), clamped to `max_total`.
    pub fn sample(&self, rng: &mut Pcg32, procs: u32, max_total: u64) -> u64 {
        let per_proc_gib = rng.lognormal(self.lognorm.mu, self.lognorm.sigma);
        let per_proc = ((per_proc_gib * GIB as f64) as u64)
            .clamp(self.min_per_proc, self.max_per_proc);
        (per_proc * procs as u64).min(max_total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_rule_matches_mean() {
        let m = BbModel::default();
        let mean = 2.0 * (0.5f64).exp(); // GiB
        let cap = m.capacity_for(96) as f64 / GIB as f64;
        assert!((cap - 96.0 * mean).abs() < 1.0, "cap {cap}");
    }

    #[test]
    fn samples_respect_clamps() {
        let m = BbModel::default();
        let mut rng = Pcg32::seeded(1);
        let max_total = 100 * GIB;
        for _ in 0..10_000 {
            let procs = 1 + rng.below(96);
            let bb = m.sample(&mut rng, procs, max_total);
            assert!(bb <= max_total);
            assert!(bb >= m.min_per_proc); // at least one processor's floor
        }
    }

    #[test]
    fn sample_distribution_median_tracks_mu() {
        let m = BbModel::default();
        let mut rng = Pcg32::seeded(2);
        let mut v: Vec<u64> = (0..40_001).map(|_| m.sample(&mut rng, 1, u64::MAX)).collect();
        v.sort();
        let med = v[v.len() / 2] as f64 / GIB as f64;
        assert!((med - 2.0).abs() < 0.15, "median {med} GiB");
    }

    #[test]
    fn fit_round_trip() {
        let truth = BbModel::default();
        let mut rng = Pcg32::seeded(3);
        let samples: Vec<f64> = (0..20_000)
            .map(|_| truth.sample(&mut rng, 1, u64::MAX) as f64)
            .collect();
        let fitted = BbModel::fit_from_bytes(&samples).unwrap();
        assert!((fitted.lognorm.mu - truth.lognorm.mu).abs() < 0.1);
        assert!((fitted.lognorm.sigma - truth.lognorm.sigma).abs() < 0.1);
    }
}
