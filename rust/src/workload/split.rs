//! Workload splitting for the paper's robustness analysis (Figs 11-12):
//! "we split the workload into 16 non-overlapping, three-week-long
//! parts", simulate each part independently, and normalise each policy's
//! per-part averages by the sjf-bb reference.

use crate::core::job::{Job, JobId};
use crate::core::time::{Duration, Time};

/// Split `jobs` (sorted by submit) into `n_parts` consecutive windows of
/// `part_weeks` weeks each, re-zeroing submit times inside every part.
/// Jobs past the last window are dropped (mirrors the paper's fixed
/// 16 x 3 weeks over an ~48-week trace).
pub fn split_workload(jobs: &[Job], n_parts: usize, part_weeks: f64) -> Vec<Vec<Job>> {
    let part_span = Duration::from_secs_f64(part_weeks * 7.0 * 24.0 * 3600.0);
    let mut parts: Vec<Vec<Job>> = vec![Vec::new(); n_parts];
    for j in jobs {
        let idx = (j.submit.0 / part_span.0.max(1)) as usize;
        if idx >= n_parts {
            continue;
        }
        let part_start = Time(part_span.0 * idx as u64);
        let mut job = j.clone();
        job.submit = Time(j.submit.0 - part_start.0);
        job.id = JobId(parts[idx].len() as u32);
        parts[idx].push(job);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(submit_s: u64) -> Job {
        Job {
            id: JobId(0),
            submit: Time::from_secs(submit_s),
            walltime: Duration::from_mins(10),
            compute_time: Duration::from_mins(5),
            procs: 1,
            bb: 1,
            phases: 1,
        }
    }

    #[test]
    fn assigns_and_rezeroes() {
        let week = 7 * 24 * 3600;
        let jobs = vec![job(0), job(week), job(3 * week + 5), job(6 * week + 1)];
        let parts = split_workload(&jobs, 2, 3.0);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].len(), 2);
        assert_eq!(parts[1].len(), 1); // the 6-week job is dropped
        assert_eq!(parts[1][0].submit, Time::from_secs(5));
        // Ids re-assigned densely within a part.
        assert_eq!(parts[0][1].id, JobId(1));
    }

    #[test]
    fn paper_shape_16x3() {
        // 48 weeks of one job per week -> 16 parts x 3 jobs.
        let week = 7 * 24 * 3600;
        let jobs: Vec<Job> = (0..48).map(|w| job(w * week + 10)).collect();
        let parts = split_workload(&jobs, 16, 3.0);
        assert_eq!(parts.len(), 16);
        assert!(parts.iter().all(|p| p.len() == 3));
    }

    #[test]
    fn empty_input() {
        let parts = split_workload(&[], 16, 3.0);
        assert_eq!(parts.len(), 16);
        assert!(parts.iter().all(|p| p.is_empty()));
    }
}
