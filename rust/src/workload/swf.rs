//! Parser for the Standard Workload Format (SWF) of the Parallel
//! Workloads Archive (Feitelson et al.), so the real KTH-SP2-1996-2.1-cln
//! log can be dropped into the pipeline unchanged when available. Jobs
//! missing a memory column get burst-buffer requests from the
//! [`crate::workload::bbmodel::BbModel`].

use crate::core::job::{Job, JobId};
use crate::core::time::{Duration, Time};
use crate::stats::rng::Pcg32;
use crate::workload::bbmodel::BbModel;

/// One raw SWF record (the 18 standard fields we care about).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwfRecord {
    pub job_id: i64,
    pub submit: i64,
    pub wait: i64,
    pub run: i64,
    pub procs_alloc: i64,
    pub mem_used_kb: i64,
    pub procs_req: i64,
    pub walltime_req: i64,
    pub mem_req_kb: i64,
    pub status: i64,
}

/// Parse SWF text. Lines starting with `;` are header comments. Returns
/// records in file order, skipping malformed lines (counted).
pub fn parse_swf(text: &str) -> (Vec<SwfRecord>, usize) {
    let mut records = Vec::new();
    let mut skipped = 0;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with(';') {
            continue;
        }
        let f: Vec<i64> = line
            .split_whitespace()
            .map(|t| t.parse::<f64>().map(|v| v as i64).unwrap_or(-1))
            .collect();
        if f.len() < 11 {
            skipped += 1;
            continue;
        }
        records.push(SwfRecord {
            job_id: f[0],
            submit: f[1],
            wait: f[2],
            run: f[3],
            procs_alloc: f[4],
            mem_used_kb: f[6],
            procs_req: f[7],
            walltime_req: f[8],
            mem_req_kb: f[9],
            status: f[10],
        });
    }
    (records, skipped)
}

/// Options controlling the SWF -> [`Job`] conversion.
#[derive(Debug, Clone)]
pub struct SwfConvert {
    /// Machine size to clamp processor requests to (paper: 96).
    pub max_procs: u32,
    /// Floor on walltime relative to runtime so the I/O stretching of the
    /// Fig-4 model does not mass-kill jobs with exact estimates.
    pub walltime_factor_min: f64,
    /// Maximum total burst-buffer request per job (typically a fraction
    /// of capacity so every job remains schedulable).
    pub max_bb_total: u64,
    /// Burst-buffer model for logs without a usable memory column.
    pub bb_model: BbModel,
    pub seed: u64,
}

/// Convert records to simulator jobs: extract submit/walltime/processors
/// (the paper's fields), use runtime as ground-truth compute time, fill
/// burst buffers from the memory column when present, else sample.
pub fn records_to_jobs(records: &[SwfRecord], opt: &SwfConvert) -> Vec<Job> {
    let mut rng = Pcg32::seeded(opt.seed);
    let mut jobs = Vec::with_capacity(records.len());
    let t0 = records.iter().map(|r| r.submit).filter(|&s| s >= 0).min().unwrap_or(0);
    for r in records {
        let run = r.run.max(0);
        if run == 0 {
            continue; // cancelled before start
        }
        let procs = r.procs_req.max(r.procs_alloc).max(1).min(opt.max_procs as i64) as u32;
        let submit = Time::from_secs((r.submit - t0).max(0) as u64);
        let compute = Duration::from_secs(run as u64);
        let wall_req = if r.walltime_req > 0 { r.walltime_req } else { run };
        let wall = Duration::from_secs(wall_req.max(run) as u64)
            .max(compute.mul_f64(opt.walltime_factor_min));
        // Memory column is per processor in KB in SWF.
        let bb = if r.mem_req_kb > 0 || r.mem_used_kb > 0 {
            let per_proc_b = r.mem_req_kb.max(r.mem_used_kb) as u64 * 1024;
            (per_proc_b * procs as u64).min(opt.max_bb_total)
        } else {
            opt.bb_model.sample(&mut rng, procs, opt.max_bb_total)
        };
        let phases = 1 + rng.below(10);
        jobs.push(Job {
            id: JobId(jobs.len() as u32),
            submit,
            walltime: wall,
            compute_time: compute,
            procs,
            bb,
            phases,
        });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
; Version: 2.2
; Computer: IBM SP2
; the paper's log: KTH-SP2-1996-2.1-cln
1 0 10 300 4 -1 2048 4 600 2048 1 1 1 -1 -1 -1 -1 -1
2 60 0 100 8 -1 -1 8 200 -1 1 2 1 -1 -1 -1 -1 -1
3 120 5 0 1 -1 -1 1 100 -1 5 3 1 -1 -1 -1 -1 -1
bad line
4 180 0 50 200 -1 -1 200 100 -1 1 4 1 -1 -1 -1 -1 -1
";

    fn opts() -> SwfConvert {
        SwfConvert {
            max_procs: 96,
            walltime_factor_min: 1.25,
            max_bb_total: 1 << 40,
            bb_model: BbModel::default(),
            seed: 7,
        }
    }

    #[test]
    fn parses_and_skips_malformed() {
        let (recs, skipped) = parse_swf(SAMPLE);
        assert_eq!(recs.len(), 4);
        assert_eq!(skipped, 1);
        assert_eq!(recs[0].procs_req, 4);
        assert_eq!(recs[0].mem_req_kb, 2048);
        assert_eq!(recs[1].walltime_req, 200);
    }

    #[test]
    fn conversion_drops_zero_runtime_and_clamps() {
        let (recs, _) = parse_swf(SAMPLE);
        let jobs = records_to_jobs(&recs, &opts());
        // Job 3 (run=0) dropped.
        assert_eq!(jobs.len(), 3);
        // Job 4's 200 procs clamped to 96.
        assert_eq!(jobs[2].procs, 96);
        // Submit times re-zeroed to the first record.
        assert_eq!(jobs[0].submit, Time::ZERO);
        assert_eq!(jobs[1].submit, Time::from_secs(60));
    }

    #[test]
    fn memory_column_becomes_bb_when_present() {
        let (recs, _) = parse_swf(SAMPLE);
        let jobs = records_to_jobs(&recs, &opts());
        // Job 1: 2048 KB/proc * 4 procs = 8 MiB.
        assert_eq!(jobs[0].bb, 2048 * 1024 * 4);
        // Job 2 has no memory column: sampled, non-zero.
        assert!(jobs[1].bb > 0);
    }

    #[test]
    fn walltime_floor_applies() {
        let (recs, _) = parse_swf(SAMPLE);
        let jobs = records_to_jobs(&recs, &opts());
        for j in &jobs {
            assert!(j.walltime.as_secs_f64() >= j.compute_time.as_secs_f64() * 1.25 - 1e-6);
        }
    }

    #[test]
    fn deterministic_sampling() {
        let (recs, _) = parse_swf(SAMPLE);
        let a = records_to_jobs(&recs, &opts());
        let b = records_to_jobs(&recs, &opts());
        assert_eq!(a, b);
    }
}
