//! Ablation benches for the design choices DESIGN.md calls out:
//!  - our SA schedule (189 evals) vs Zheng et al. (8742 evals): quality
//!    per evaluation (§3.3's central claim);
//!  - initial candidates only (no annealing) vs full SA;
//!  - scorer backends: exact profile vs native discrete vs XLA artifact;
//!  - plan-scheduler memoisation on quiet ticks.

use bbsched::core::job::JobId;
use bbsched::core::resources::Resources;
use bbsched::core::time::{Duration, Time};
use bbsched::report::bench::{bench, report, BenchResult};
use bbsched::sched::plan::annealing::{optimise, PermScorer, SaParams};
use bbsched::sched::plan::builder::PlanJob;
use bbsched::sched::plan::candidates::initial_candidates;
use bbsched::sched::plan::scheduler::ExternalBatchScorer;
use bbsched::sched::plan::scorer::{DiscreteProblem, ExactScorer, NativeDiscreteScorer};
use bbsched::sched::plan::zheng::{optimise_zheng, ZhengParams};
use bbsched::sched::timeline::Profile;
use bbsched::stats::rng::Pcg32;
use bbsched::workload::bbmodel::BbModel;

fn snapshot(rng: &mut Pcg32, n: usize) -> (Profile, Vec<PlanJob>, Time) {
    let bb_model = BbModel::default();
    let capacity = Resources::new(96, bb_model.capacity_for(96));
    let now = Time::from_secs(3600);
    let mut base = Profile::flat(now, capacity);
    // Some running load.
    for _ in 0..6 {
        let a = now + Duration::from_secs(rng.below(600) as u64);
        let b = a + Duration::from_secs(600 + rng.below(7200) as u64);
        let req = Resources::new(1 + rng.below(16), (rng.below(40) as u64) << 30);
        if base.min_free(a, b).fits(&req) {
            base.subtract(a, b, req);
        }
    }
    let jobs: Vec<PlanJob> = (0..n)
        .map(|i| {
            let procs = 1 + rng.below(48);
            PlanJob {
                id: JobId(i as u32),
                req: Resources::new(procs, bb_model.sample(rng, procs, capacity.bb / 2)),
                walltime: Duration::from_secs(60 * (5 + rng.below(600)) as u64),
                submit: Time::from_secs(rng.below(3600) as u64),
            }
        })
        .collect();
    (base, jobs, now)
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Pcg32::seeded(7);
    let (base, jobs, now) = snapshot(&mut rng, 24);
    let cands = initial_candidates(&jobs);

    // --- SA schedules: ours vs Zheng (quality computed once up front). --
    let quality = {
        let mut s1 = ExactScorer::new(&base, &jobs, now, 2.0);
        let mut r1 = Pcg32::seeded(99);
        let ours = optimise(&mut s1, jobs.len(), &cands, &SaParams::default(), &mut r1);
        let mut s2 = ExactScorer::new(&base, &jobs, now, 2.0);
        let mut r2 = Pcg32::seeded(99);
        let zheng = optimise_zheng(&mut s2, jobs.len(), &ZhengParams::default(), &mut r2);
        (ours.score, zheng.score, ours.evaluations, zheng.evaluations)
    };
    results.push(bench(
        "sa_ours_189_evals",
        1,
        10,
        || {
            let mut scorer = ExactScorer::new(&base, &jobs, now, 2.0);
            let mut r = Pcg32::seeded(99);
            optimise(&mut scorer, jobs.len(), &cands, &SaParams::default(), &mut r)
        },
        |o| format!("score {:.3e}, {} evals", o.score, o.evaluations),
    ));
    results.push(bench(
        "sa_zheng_8742_evals",
        0,
        3,
        || {
            let mut scorer = ExactScorer::new(&base, &jobs, now, 2.0);
            let mut r = Pcg32::seeded(99);
            optimise_zheng(&mut scorer, jobs.len(), &ZhengParams::default(), &mut r)
        },
        |o| format!("score {:.3e}, {} evals", o.score, o.evaluations),
    ));

    // --- Candidates only (skip annealing). ------------------------------
    results.push(bench(
        "init_candidates_only",
        1,
        10,
        || {
            let mut scorer = ExactScorer::new(&base, &jobs, now, 2.0);
            cands
                .iter()
                .map(|c| scorer.score(c))
                .fold(f64::INFINITY, f64::min)
        },
        |s| format!("best candidate score {s:.3e}"),
    ));

    // --- Scorer backends (score the same 189-eval budget). ---------------
    results.push(bench(
        "backend_exact_profile",
        1,
        5,
        || {
            let mut scorer = ExactScorer::new(&base, &jobs, now, 2.0);
            let mut r = Pcg32::seeded(5);
            optimise(&mut scorer, jobs.len(), &cands, &SaParams::default(), &mut r).score
        },
        |s| format!("score {s:.3e}"),
    ));
    results.push(bench(
        "backend_native_discrete",
        1,
        5,
        || {
            let problem = DiscreteProblem::build(&base, &jobs, now, 256, 2.0);
            let mut scorer = NativeDiscreteScorer::new(problem);
            let mut r = Pcg32::seeded(5);
            optimise(&mut scorer, jobs.len(), &cands, &SaParams::default(), &mut r).score
        },
        |s| format!("score {s:.3e}"),
    ));
    if let Ok(mut xla) =
        bbsched::runtime::scorer::XlaScorer::from_artifact_dir(std::path::Path::new("artifacts"))
    {
        let problem = DiscreteProblem::build(&base, &jobs, now, 256, 2.0);
        let perms: Vec<Vec<usize>> = cands.clone();
        results.push(bench(
            "backend_xla_batch9",
            1,
            10,
            || xla.score_batch(&problem, &perms),
            |s| format!("9 perms -> {} scores (first {:.3e})", s.len(), s[0]),
        ));
    } else {
        eprintln!("note: artifacts/ missing, skipping backend_xla_batch9");
    }

    // --- Memoisation. -----------------------------------------------------
    use bbsched::sched::plan::scheduler::PlanSched;
    use bbsched::sched::{CtxHarness, SchedView};
    let reqs: Vec<bbsched::JobRequest> = jobs
        .iter()
        .map(|j| bbsched::JobRequest {
            id: j.id,
            submit: j.submit,
            walltime: j.walltime,
            procs: j.req.cpu,
            bb: j.req.bb,
        })
        .collect();
    let running = [bbsched::sched::RunningInfo {
        id: JobId(999),
        req: Resources::new(96, 0),
        expected_end: Time::from_secs(360_000),
    }];
    let view = SchedView {
        now,
        capacity: Resources::new(96, BbModel::default().capacity_for(96)),
        free: Resources::new(0, BbModel::default().capacity_for(96)),
        queue: &reqs,
        running: &running,
    };
    let mut sched = PlanSched::new(2.0, 1);
    let mut harness = CtxHarness::from_view(&view);
    // Prime the memo.
    let _ = bbsched::sched::Scheduler::schedule(&mut sched, &mut harness.ctx(view));
    results.push(bench(
        "plan_sched_memoised_tick",
        10,
        1000,
        || bbsched::sched::Scheduler::schedule(&mut sched, &mut harness.ctx(view)).len(),
        |n| format!("{n} launches (memo hit)"),
    ));

    report("ablations", &results);
    println!(
        "\nSA quality: ours {:.4e} ({} evals) vs zheng {:.4e} ({} evals) -> ratio {:.4} at {:.1}% of the evaluations",
        quality.0,
        quality.2,
        quality.1,
        quality.3,
        quality.0 / quality.1,
        quality.2 as f64 / quality.3 as f64 * 100.0
    );
}
