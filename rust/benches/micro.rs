//! Microbenchmarks of the hot paths identified in DESIGN.md §7 —
//! the inputs to the EXPERIMENTS.md §Perf iteration log:
//!  - availability-profile earliest_fit / reserve,
//!  - full plan build per candidate permutation,
//!  - max-min flow rate recomputation,
//!  - event-queue throughput,
//!  - simulator end-to-end step rate,
//!  - XLA scorer latency per batched execution.

use bbsched::core::job::JobId;
use bbsched::core::resources::Resources;
use bbsched::core::time::{Duration, Time};
use bbsched::coordinator::run_policy;
use bbsched::platform::flows::FlowNetwork;
use bbsched::report::bench::{bench, report, BenchResult};
use bbsched::sched::plan::builder::{build_plan, PlanJob};
use bbsched::sched::plan::scorer::DiscreteProblem;
use bbsched::sched::timeline::Profile;
use bbsched::sched::Policy;
use bbsched::sim::events::{Event, EventQueue};
use bbsched::stats::rng::Pcg32;
use bbsched::workload::bbmodel::BbModel;
use bbsched::workload::synth::{generate, SynthConfig};
use bbsched::SimOptions;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Pcg32::seeded(3);
    let capacity = Resources::new(96, BbModel::default().capacity_for(96));

    // A profile with ~60 breakpoints (a busy cluster).
    let mut profile = Profile::flat(Time::ZERO, capacity);
    for _ in 0..30 {
        let a = Time::from_secs(rng.below(50_000) as u64);
        let b = a + Duration::from_secs(600 + rng.below(20_000) as u64);
        let req = Resources::new(1 + rng.below(8), (rng.below(20) as u64) << 30);
        if profile.min_free(a, b).fits(&req) {
            profile.subtract(a, b, req);
        }
    }
    let jobs: Vec<PlanJob> = (0..32)
        .map(|i| {
            let procs = 1 + rng.below(48);
            PlanJob {
                id: JobId(i),
                req: Resources::new(
                    procs,
                    BbModel::default().sample(&mut rng, procs, capacity.bb / 2),
                ),
                walltime: Duration::from_secs(60 * (5 + rng.below(600)) as u64),
                submit: Time::ZERO,
            }
        })
        .collect();

    results.push(bench(
        "profile_earliest_fit",
        100,
        10_000,
        || {
            profile.earliest_fit(Resources::new(24, 50 << 30), Duration::from_secs(3600), Time::ZERO)
        },
        |t| format!("-> {t}"),
    ));
    results.push(bench(
        "profile_clone_reserve",
        100,
        10_000,
        || {
            let mut p = profile.clone();
            p.reserve(Time::from_secs(1000), Duration::from_secs(600), Resources::new(8, 1 << 30));
            p.len()
        },
        |n| format!("{n} breakpoints"),
    ));
    results.push(bench(
        "plan_build_32_jobs",
        10,
        1_000,
        || build_plan(&profile, &jobs, &(0..32).collect::<Vec<_>>(), Time::ZERO, 2.0).score,
        |s| format!("score {s:.3e}"),
    ));
    results.push(bench(
        "discretise_T256",
        10,
        1_000,
        || DiscreteProblem::build(&profile, &jobs, Time::ZERO, 256, 2.0).dt,
        |dt| format!("dt {dt:.1} s"),
    ));

    // Flow network: 200 flows over 400 links.
    let caps: Vec<f64> = (0..400).map(|_| rng.range_f64(1e9, 5e9)).collect();
    let mut net = FlowNetwork::new(caps);
    for tag in 0..200 {
        let route: Vec<usize> = (0..3).map(|_| rng.below(400) as usize).collect();
        net.add_flow(route, 1e9, tag);
    }
    results.push(bench(
        "flow_recompute_200f_400l",
        10,
        1_000,
        || {
            net.recompute_rates();
            net.n_active()
        },
        |n| format!("{n} flows"),
    ));

    // Event queue throughput.
    results.push(bench(
        "event_queue_push_pop_10k",
        5,
        200,
        || {
            let mut q = EventQueue::new();
            for i in 0..10_000u32 {
                q.push(Time::from_secs(((i as u64) * 7919) % 100_000), Event::JobArrival(JobId(i)));
            }
            let mut n = 0;
            while q.pop().is_some() {
                n += 1;
            }
            n
        },
        |n| format!("{n} events"),
    ));

    // End-to-end simulator rate: 285-job workload with I/O.
    let wl = SynthConfig::scaled(1, 0.01);
    let wl_jobs = generate(&wl);
    let sim = SimOptions::new().bb_capacity(wl.bb_capacity);
    results.push(bench(
        "sim_285_jobs_sjf_bb_io",
        1,
        5,
        || {
            run_policy(wl_jobs.clone(), Policy::SjfBb, &sim)
                .records
                .len()
        },
        |n| format!("{n} jobs simulated"),
    ));
    results.push(bench(
        "sim_285_jobs_plan2_exact",
        0,
        3,
        || {
            run_policy(wl_jobs.clone(), Policy::Plan(2), &sim)
                .records
                .len()
        },
        |n| format!("{n} jobs simulated"),
    ));

    // XLA scorer latency per batch (K=8 perms, Q<=64, T=256).
    if let Ok(mut xla) =
        bbsched::runtime::scorer::XlaScorer::from_artifact_dir(std::path::Path::new("artifacts"))
    {
        use bbsched::sched::plan::scheduler::ExternalBatchScorer;
        let problem = DiscreteProblem::build(&profile, &jobs, Time::ZERO, 256, 2.0);
        let perms: Vec<Vec<usize>> = (0..8)
            .map(|_| {
                let mut p: Vec<usize> = (0..jobs.len()).collect();
                rng.shuffle(&mut p);
                p
            })
            .collect();
        results.push(bench(
            "xla_score_batch8_q32_t256",
            3,
            50,
            || xla.score_batch(&problem, &perms)[0],
            |s| format!("first score {s:.3e}"),
        ));
        println!(
            "xla executions {} / fallbacks {}",
            xla.executions, xla.fallback_scores
        );
    } else {
        eprintln!("note: artifacts/ missing, skipping xla_score_batch8");
    }

    report("micro", &results);
}
