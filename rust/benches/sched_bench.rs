//! Scheduler-cost bench: `sched_wall` and invocation counts per policy
//! on a ~10k-job synthetic workload, measured twice per policy —
//!
//! - `incremental`: the shared [`ResourceTimeline`] maintained by the
//!   simulator (the default), prefix-cached plan scoring;
//! - `rebuild`: the pre-refactor cost model — the timeline rebuilt from
//!   the running set on every invocation, cold plan scoring.
//!
//! Both modes are fingerprint-identical by construction (asserted here);
//! only the wall-clock differs. Emits `BENCH_sched.json` (override the
//! path with `BENCH_OUT`) to feed the perf trajectory.
//!
//! Usage: `cargo bench --bench sched_bench` (full ~10k-job workload) or
//! `cargo bench --bench sched_bench -- --quick` (CI smoke size).

use bbsched::coordinator::{run_policy_opts, PlanBackendKind, SchedOpts};
use bbsched::report::bench::{fmt_dur, write_json, BenchResult};
use bbsched::report::{fmt_f, render_table};
use bbsched::sched::Policy;
use bbsched::sim::simulator::SimConfig;
use bbsched::workload::synth::{generate, SynthConfig};
use std::time::Duration;

struct Row {
    policy: String,
    invocations: u64,
    incremental: Duration,
    rebuild: Duration,
    fingerprint: u64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // scale 1.0 == 28,453 jobs / 48 weeks; 0.35 lands at ~10k jobs.
    let scale = if quick { 0.01 } else { 0.35 };
    let cfg = SynthConfig::scaled(1, scale);
    let jobs = generate(&cfg);
    // Pure scheduling cost: I/O off so runtime == compute time and every
    // second of wall-clock difference is scheduler-side.
    let sim = SimConfig { bb_capacity: cfg.bb_capacity, io_enabled: false, ..SimConfig::default() };
    let policies = [
        Policy::Fcfs,
        Policy::FcfsEasy,
        Policy::Filler,
        Policy::FcfsBb,
        Policy::SjfBb,
        Policy::SlurmLike,
        Policy::ConservativeBb,
        Policy::Plan(1),
        Policy::Plan(2),
    ];
    eprintln!(
        "sched bench: {} jobs (scale {scale}), {} policies x 2 timeline modes",
        jobs.len(),
        policies.len()
    );

    let mut rows: Vec<Row> = Vec::new();
    for policy in policies {
        let inc = run_policy_opts(
            jobs.clone(),
            policy,
            &sim,
            1,
            PlanBackendKind::Exact,
            SchedOpts::default(),
        );
        let reb_cfg = SimConfig { rebuild_timeline: true, ..sim.clone() };
        let reb = run_policy_opts(
            jobs.clone(),
            policy,
            &reb_cfg,
            1,
            PlanBackendKind::Exact,
            SchedOpts { plan_cold_scoring: true, ..SchedOpts::default() },
        );
        assert_eq!(
            inc.fingerprint(),
            reb.fingerprint(),
            "{}: timeline modes must be behaviour-identical",
            policy.name()
        );
        assert_eq!(inc.sched_invocations, reb.sched_invocations);
        eprintln!(
            "  {:>16}: {} invocations, incremental {} vs rebuild {}",
            policy.name(),
            inc.sched_invocations,
            fmt_dur(inc.sched_wall),
            fmt_dur(reb.sched_wall),
        );
        rows.push(Row {
            policy: policy.name(),
            invocations: inc.sched_invocations,
            incremental: inc.sched_wall,
            rebuild: reb.sched_wall,
            fingerprint: inc.fingerprint(),
        });
    }

    // --- Table. -----------------------------------------------------------
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.policy.clone(),
                r.invocations.to_string(),
                fmt_dur(r.incremental),
                fmt_dur(r.rebuild),
                fmt_f(r.rebuild.as_secs_f64() / r.incremental.as_secs_f64().max(1e-12)),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &format!("sched_wall per policy ({} jobs, io off)", jobs.len()),
            &["policy", "invocations", "incremental", "rebuild", "speedup"],
            &table,
        )
    );

    // --- BENCH_sched.json (the perf-trajectory contract). -----------------
    let results: Vec<BenchResult> = rows
        .iter()
        .map(|r| BenchResult {
            name: r.policy.clone(),
            iters: 1,
            mean: r.incremental,
            stddev: Duration::ZERO,
            min: r.incremental,
            note: format!(
                "invocations={} rebuild_s={:.6} speedup={:.3} fingerprint={:016x} jobs={}",
                r.invocations,
                r.rebuild.as_secs_f64(),
                r.rebuild.as_secs_f64() / r.incremental.as_secs_f64().max(1e-12),
                r.fingerprint,
                jobs.len(),
            ),
        })
        .collect();
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_sched.json".to_string());
    write_json(std::path::Path::new(&out), "sched_wall", &results).expect("write bench json");
    println!("bench json -> {out}");
}
