//! Scheduler-cost bench: `sched_wall` and invocation counts per policy
//! on a ~10k-job synthetic workload, measured twice per policy —
//!
//! - `incremental`: the shared [`ResourceTimeline`] maintained by the
//!   simulator (the default), prefix-cached plan scoring;
//! - `rebuild`: the pre-refactor cost model — the timeline rebuilt from
//!   the running set on every invocation, cold plan scoring.
//!
//! Both modes are fingerprint-identical by construction (asserted here);
//! only the wall-clock differs.
//!
//! A second suite sweeps the plan-optimiser knob ablation on a `storm:4`
//! backlog workload — {cold, delta, delta+warm, delta+warm+window} —
//! where `cold` disables the prefix/delta cache (the bit-exactness
//! oracle: its fingerprint must equal `delta`'s), `warm` seeds SA from
//! the previous tick's plan and `window` bounds the SA problem to the
//! 32 most urgent queued jobs. A third suite repeats the storm under
//! per-node placement — {aggregate, group-aware} x {delta, cold} — to
//! price the group-aware scoring lane (cold is the oracle in both lane
//! modes). Everything lands in one `BENCH_sched.json`
//! (override the path with `BENCH_OUT`) — the perf trajectory the CI
//! `bench-gate` job enforces a regression threshold over.
//!
//! Usage: `cargo bench --bench sched_bench` (full ~10k-job workload) or
//! `cargo bench --bench sched_bench -- --quick` (CI smoke size).

use bbsched::coordinator::run_policy;
use bbsched::platform::{BbArch, PlatformSpec, TopologyConfig};
use bbsched::report::bench::{fmt_dur, write_json, BenchResult};
use bbsched::report::{fmt_f, render_table};
use bbsched::sched::Policy;
use bbsched::workload::synth::{generate, SynthConfig};
use bbsched::workload::{EstimateModel, Family, Scenario, WorkloadSpec};
use bbsched::SimOptions;
use std::time::Duration;

struct Row {
    policy: String,
    invocations: u64,
    incremental: Duration,
    rebuild: Duration,
    fingerprint: u64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // scale 1.0 == 28,453 jobs / 48 weeks; 0.35 lands at ~10k jobs.
    let scale = if quick { 0.01 } else { 0.35 };
    let cfg = SynthConfig::scaled(1, scale);
    let jobs = generate(&cfg);
    // Pure scheduling cost: I/O off so runtime == compute time and every
    // second of wall-clock difference is scheduler-side.
    let sim = SimOptions::new().bb_capacity(cfg.bb_capacity).io(false);
    let policies = [
        Policy::Fcfs,
        Policy::FcfsEasy,
        Policy::Filler,
        Policy::FcfsBb,
        Policy::SjfBb,
        Policy::SlurmLike,
        Policy::ConservativeBb,
        Policy::Plan(1),
        Policy::Plan(2),
    ];
    eprintln!(
        "sched bench: {} jobs (scale {scale}), {} policies x 2 timeline modes",
        jobs.len(),
        policies.len()
    );

    let mut rows: Vec<Row> = Vec::new();
    for policy in policies {
        let inc = run_policy(jobs.clone(), policy, &sim);
        let reb_opts = sim.clone().rebuild_timeline(true).plan_cold_scoring(true);
        let reb = run_policy(jobs.clone(), policy, &reb_opts);
        assert_eq!(
            inc.fingerprint(),
            reb.fingerprint(),
            "{}: timeline modes must be behaviour-identical",
            policy.name()
        );
        assert_eq!(inc.sched_invocations, reb.sched_invocations);
        eprintln!(
            "  {:>16}: {} invocations, incremental {} vs rebuild {}",
            policy.name(),
            inc.sched_invocations,
            fmt_dur(inc.sched_wall),
            fmt_dur(reb.sched_wall),
        );
        rows.push(Row {
            policy: policy.name(),
            invocations: inc.sched_invocations,
            incremental: inc.sched_wall,
            rebuild: reb.sched_wall,
            fingerprint: inc.fingerprint(),
        });
    }

    // --- Plan-optimiser ablation on a storm backlog. ----------------------
    // Windowing only bites when queues pile up, so the sweep runs on the
    // arrival-storm family (window W=32, the plan-perf campaign's value).
    let storm = Scenario {
        workload: WorkloadSpec {
            family: Family::ArrivalStorm { intensity: 4.0 },
            scale,
            estimate: EstimateModel::Paper,
        },
        platform: PlatformSpec { bb_arch: BbArch::Shared, bb_factor: 1.0 },
    };
    let (storm_jobs, storm_bb) =
        storm.materialise(1, &TopologyConfig::default()).expect("storm workload");
    let storm_sim = SimOptions::new().bb_capacity(storm_bb).io(false);
    let ablation: [(&str, SimOptions); 4] = [
        ("cold", storm_sim.clone().plan_cold_scoring(true)),
        ("delta", storm_sim.clone()),
        ("delta+warm", storm_sim.clone().plan_warm_start(true)),
        ("delta+warm+window", storm_sim.clone().plan_warm_start(true).plan_window(32)),
    ];
    eprintln!("plan ablation: {} storm jobs, plan-2 x {} configs", storm_jobs.len(), 4);
    let mut plan_rows: Vec<(String, Duration, u64, f64, u64)> = Vec::new();
    for (cfg, opts) in ablation {
        let res = run_policy(storm_jobs.clone(), Policy::Plan(2), &opts);
        let mean_wait_h = {
            let s = bbsched::metrics::summary::summarize("plan-2", &res.records);
            s.mean_wait_h
        };
        eprintln!(
            "  {:>18}: sched_wall {} ({} invocations, mean wait {:.3} h)",
            cfg,
            fmt_dur(res.sched_wall),
            res.sched_invocations,
            mean_wait_h,
        );
        plan_rows.push((
            cfg.to_string(),
            res.sched_wall,
            res.sched_invocations,
            mean_wait_h,
            res.fingerprint(),
        ));
    }
    // Delta scoring is a pure cache: bit-identical to the cold oracle.
    assert_eq!(
        plan_rows[0].4, plan_rows[1].4,
        "delta scoring must be behaviour-identical to the cold scorer"
    );

    // --- Group-aware ablation on a per-node storm. ------------------------
    // The group-aware lane only bites under per-node placement, so this
    // sweep runs the same storm against the per-node architecture:
    // {aggregate, group} x {delta, cold}. Cold scoring stays the
    // bit-exactness oracle within each lane mode.
    let pernode = Scenario {
        workload: WorkloadSpec {
            family: Family::ArrivalStorm { intensity: 4.0 },
            scale,
            estimate: EstimateModel::Paper,
        },
        platform: PlatformSpec { bb_arch: BbArch::PerNode, bb_factor: 1.0 },
    };
    let (pn_jobs, pn_bb) =
        pernode.materialise(1, &TopologyConfig::default()).expect("per-node storm workload");
    let pn_sim = SimOptions::new().bb(pn_bb, BbArch::PerNode.placement()).io(false);
    let pn_ablation: [(&str, SimOptions); 4] = [
        ("agg", pn_sim.clone()),
        ("agg-cold", pn_sim.clone().plan_cold_scoring(true)),
        ("group", pn_sim.clone().plan_group_aware(true)),
        ("group-cold", pn_sim.clone().plan_group_aware(true).plan_cold_scoring(true)),
    ];
    eprintln!("per-node ablation: {} storm jobs, plan-2 x {} configs", pn_jobs.len(), 4);
    let mut pn_rows: Vec<(String, Duration, u64, f64, u64)> = Vec::new();
    for (cfg, opts) in pn_ablation {
        let res = run_policy(pn_jobs.clone(), Policy::Plan(2), &opts);
        let mean_wait_h = {
            let s = bbsched::metrics::summary::summarize("plan-2", &res.records);
            s.mean_wait_h
        };
        eprintln!(
            "  {:>18}: sched_wall {} ({} invocations, mean wait {:.3} h)",
            cfg,
            fmt_dur(res.sched_wall),
            res.sched_invocations,
            mean_wait_h,
        );
        pn_rows.push((
            cfg.to_string(),
            res.sched_wall,
            res.sched_invocations,
            mean_wait_h,
            res.fingerprint(),
        ));
    }
    // The cold scorer stays the oracle in both lane modes.
    assert_eq!(
        pn_rows[0].4, pn_rows[1].4,
        "per-node aggregate delta scoring must match its cold oracle"
    );
    assert_eq!(
        pn_rows[2].4, pn_rows[3].4,
        "group-aware delta scoring must match its cold oracle"
    );

    // --- Table. -----------------------------------------------------------
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.policy.clone(),
                r.invocations.to_string(),
                fmt_dur(r.incremental),
                fmt_dur(r.rebuild),
                fmt_f(r.rebuild.as_secs_f64() / r.incremental.as_secs_f64().max(1e-12)),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &format!("sched_wall per policy ({} jobs, io off)", jobs.len()),
            &["policy", "invocations", "incremental", "rebuild", "speedup"],
            &table,
        )
    );
    let baseline_wall = plan_rows[0].1;
    let plan_table: Vec<Vec<String>> = plan_rows
        .iter()
        .map(|(cfg, wall, inv, wait, fp)| {
            vec![
                cfg.clone(),
                inv.to_string(),
                fmt_dur(*wall),
                fmt_f(baseline_wall.as_secs_f64() / wall.as_secs_f64().max(1e-12)),
                fmt_f(*wait),
                format!("{fp:016x}"),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &format!("plan-2 ablation, storm:4 workload ({} jobs)", storm_jobs.len()),
            &["config", "invocations", "sched_wall", "speedup vs cold", "mean wait [h]",
              "fingerprint"],
            &plan_table,
        )
    );
    let pn_table: Vec<Vec<String>> = pn_rows
        .iter()
        .map(|(cfg, wall, inv, wait, fp)| {
            vec![
                cfg.clone(),
                inv.to_string(),
                fmt_dur(*wall),
                fmt_f(*wait),
                format!("{fp:016x}"),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &format!(
                "plan-2 group-aware ablation, per-node storm:4 workload ({} jobs)",
                pn_jobs.len()
            ),
            &["config", "invocations", "sched_wall", "mean wait [h]", "fingerprint"],
            &pn_table,
        )
    );

    // --- BENCH_sched.json (the perf-trajectory contract). -----------------
    let mut results: Vec<BenchResult> = rows
        .iter()
        .map(|r| BenchResult {
            name: r.policy.clone(),
            iters: 1,
            mean: r.incremental,
            stddev: Duration::ZERO,
            min: r.incremental,
            note: format!(
                "invocations={} rebuild_s={:.6} speedup={:.3} fingerprint={:016x} jobs={}",
                r.invocations,
                r.rebuild.as_secs_f64(),
                r.rebuild.as_secs_f64() / r.incremental.as_secs_f64().max(1e-12),
                r.fingerprint,
                jobs.len(),
            ),
        })
        .collect();
    results.extend(plan_rows.iter().map(|(cfg, wall, inv, wait, fp)| BenchResult {
        name: format!("plan-2-storm/{cfg}"),
        iters: 1,
        mean: *wall,
        stddev: Duration::ZERO,
        min: *wall,
        note: format!(
            "invocations={inv} mean_wait_h={wait:.6} fingerprint={fp:016x} jobs={} \
             speedup_vs_cold={:.3}",
            storm_jobs.len(),
            baseline_wall.as_secs_f64() / wall.as_secs_f64().max(1e-12),
        ),
    }));
    results.extend(pn_rows.iter().map(|(cfg, wall, inv, wait, fp)| BenchResult {
        name: format!("plan-2-pernode-storm/{cfg}"),
        iters: 1,
        mean: *wall,
        stddev: Duration::ZERO,
        min: *wall,
        note: format!(
            "invocations={inv} mean_wait_h={wait:.6} fingerprint={fp:016x} jobs={}",
            pn_jobs.len(),
        ),
    }));
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_sched.json".to_string());
    write_json(std::path::Path::new(&out), "sched_wall", &results).expect("write bench json");
    println!("bench json -> {out}");
}
