//! One benchmark per paper figure: each regenerates that figure's data
//! series on a scaled workload (2% of the trace, full I/O contention)
//! and reports the headline values alongside wall time, so `cargo bench`
//! doubles as a fast shape-check of the reproduction.
//!
//! Full-scale numbers come from `repro eval` (see EXPERIMENTS.md).

use bbsched::coordinator::run_policy;
use bbsched::metrics::summary::summarize;
use bbsched::metrics::{bsld_letter_values, bsld_tail, waiting_letter_values, waiting_tail};
use bbsched::report::bench::{bench, report, BenchResult};
use bbsched::sched::Policy;
use bbsched::sim::simulator::SimResult;
use bbsched::SimOptions;
use bbsched::workload::split::split_workload;
use bbsched::workload::synth::{generate, SynthConfig};

const SCALE: f64 = 0.02;

fn workload() -> (Vec<bbsched::Job>, SimOptions) {
    let cfg = SynthConfig::scaled(1, SCALE);
    let jobs = generate(&cfg);
    (jobs, SimOptions::new().bb_capacity(cfg.bb_capacity))
}

fn run(jobs: &[bbsched::Job], sim: &SimOptions, p: Policy) -> SimResult {
    run_policy(jobs.to_vec(), p, sim)
}

fn main() {
    let (jobs, sim) = workload();
    let mut results: Vec<BenchResult> = Vec::new();

    // Pre-run each policy once; figure benches then measure the metric
    // regeneration over those records plus one fresh simulation to keep
    // the end-to-end cost visible.
    let fcfs_easy = run(&jobs, &sim, Policy::FcfsEasy);
    let sjf = run(&jobs, &sim, Policy::SjfBb);
    let plan2 = run(&jobs, &sim, Policy::Plan(2));

    // Fig 3: Gantt of fcfs-easy (holes before tall jobs).
    results.push(bench(
        "fig03_gantt_fcfs_easy",
        0,
        3,
        || {
            let cfg = sim.clone().record_gantt(true);
            let res = run_policy(jobs.clone(), Policy::FcfsEasy, &cfg);
            res.gantt.len()
        },
        |n| format!("{n} gantt rows"),
    ));

    // Figs 5-6: mean waiting time and bounded slowdown per policy.
    results.push(bench(
        "fig05_mean_wait",
        0,
        3,
        || {
            let res = run_policy(jobs.clone(), Policy::SjfBb, &sim);
            summarize("sjf-bb", &res.records).mean_wait_h
        },
        |v| format!("sjf-bb mean wait {v:.2} h"),
    ));
    results.push(bench(
        "fig06_mean_bsld",
        0,
        3,
        || {
            let res = run_policy(jobs.clone(), Policy::Plan(2), &sim);
            summarize("plan-2", &res.records).mean_bsld
        },
        |v| format!("plan-2 mean bsld {v:.2}"),
    ));

    // Figs 7-8: letter-value quantiles (over the pre-run records).
    results.push(bench(
        "fig07_wait_quantiles",
        1,
        20,
        || waiting_letter_values(&sjf.records).len(),
        |n| format!("{n} letter levels"),
    ));
    results.push(bench(
        "fig08_bsld_quantiles",
        1,
        20,
        || bsld_letter_values(&plan2.records).len(),
        |n| format!("{n} letter levels"),
    ));

    // Figs 9-10: top-3000 tails.
    results.push(bench(
        "fig09_wait_tail",
        1,
        20,
        || waiting_tail(&fcfs_easy.records, 3000),
        |t| format!("fcfs-easy tail max {:.1} h", t.first().copied().unwrap_or(0.0)),
    ));
    results.push(bench(
        "fig10_bsld_tail",
        1,
        20,
        || bsld_tail(&fcfs_easy.records, 3000),
        |t| format!("fcfs-easy tail max bsld {:.0}", t.first().copied().unwrap_or(0.0)),
    ));

    // Figs 11-12: split -> per-part normalised means (2 parts at bench
    // scale; 16x3 weeks at full scale).
    results.push(bench(
        "fig11_12_norm_parts",
        0,
        2,
        || {
            let parts = split_workload(&jobs, 2, 0.2);
            let mut ratios = Vec::new();
            for part in parts.iter().filter(|p| !p.is_empty()) {
                let a = run_policy(part.clone(), Policy::Plan(2), &sim);
                let b = run_policy(part.clone(), Policy::SjfBb, &sim);
                let (sa, sb) = (
                    summarize("plan-2", &a.records).mean_wait_h,
                    summarize("sjf-bb", &b.records).mean_wait_h,
                );
                if sb > 1e-12 {
                    ratios.push(sa / sb);
                }
            }
            ratios
        },
        |r| format!("plan-2/sjf-bb per-part ratios {r:?}"),
    ));

    // §4.2 headline at bench scale.
    let headline = {
        let p = summarize("plan-2", &plan2.records).mean_wait_h;
        let s = summarize("sjf-bb", &sjf.records).mean_wait_h;
        (p / s - 1.0) * 100.0
    };
    report("figures (2% workload, full I/O)", &results);
    println!("\nheadline at bench scale: plan-2 vs sjf-bb mean wait {headline:+.1}% (paper: -20%)");
}
