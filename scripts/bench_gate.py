#!/usr/bin/env python3
"""CI perf gate over BENCH_sched.json.

Compares the current bench run against the committed baseline and fails
(exit 1) when any benchmark's sched_wall mean regresses past the
threshold. Contract details:

- Baseline absent (or unreadable / empty results): skip with a notice
  and exit 0 — the gate arms itself only once a real baseline is
  committed (numbers must come from an actual bench run, never
  fabricated).
- Benchmarks are matched by `name`; names present on only one side are
  reported but never fail the gate (the ablation sweep may grow).
- Workload-size guard: every result's `note` carries `jobs=N`; entries
  whose baseline and current job counts differ by more than 1.5x are
  incomparable (e.g. a full-size baseline vs CI's `--quick` run) and
  are skipped with a notice — commit the baseline from the same
  `--quick` configuration CI runs to arm the gate for real.
- Means below --min-s are ignored: quick-mode timings of trivially fast
  policies are scheduler-noise, not signal.

Usage:
  bench_gate.py --baseline BENCH_sched.json \
                --current  BENCH_sched.current.json \
                [--threshold 1.25] [--min-s 0.05]
"""

import argparse
import json
import os
import re
import sys


def load(path):
    """name -> (mean_s, jobs-or-None) from a BENCH_*.json suite."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    out = {}
    for r in doc.get("results", []):
        m = re.search(r"\bjobs=(\d+)\b", r.get("note", ""))
        out[r["name"]] = (float(r["mean_s"]), int(m.group(1)) if m else None)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.25,
        help="fail when current mean > baseline mean * threshold",
    )
    ap.add_argument(
        "--min-s",
        type=float,
        default=0.05,
        help="ignore benchmarks whose baseline mean is below this (noise floor)",
    )
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench-gate: no committed baseline at {args.baseline}; skipping gate")
        print("bench-gate: commit a real bench run to arm the regression threshold")
        return 0
    try:
        baseline = load(args.baseline)
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"bench-gate: baseline {args.baseline} unreadable ({e}); skipping gate")
        return 0
    if not baseline:
        print(f"bench-gate: baseline {args.baseline} has no results; skipping gate")
        return 0
    current = load(args.current)

    regressions = []
    compared = 0
    for name in sorted(baseline):
        base, base_jobs = baseline[name]
        if name not in current:
            print(f"bench-gate: {name}: missing from current run (skipped)")
            continue
        cur, cur_jobs = current[name]
        if base_jobs and cur_jobs and not (1 / 1.5 <= cur_jobs / base_jobs <= 1.5):
            print(
                f"bench-gate: {name}: workload sizes differ (baseline jobs={base_jobs}, "
                f"current jobs={cur_jobs}) — incomparable, skipped"
            )
            continue
        if base < args.min_s:
            print(f"bench-gate: {name}: baseline {base:.4f}s below noise floor (skipped)")
            continue
        ratio = cur / base if base > 0 else float("inf")
        verdict = "REGRESSED" if ratio > args.threshold else "ok"
        print(f"bench-gate: {name}: {base:.4f}s -> {cur:.4f}s ({ratio:.2f}x) {verdict}")
        compared += 1
        if ratio > args.threshold:
            regressions.append((name, base, cur, ratio))
    for name in sorted(set(current) - set(baseline)):
        print(f"bench-gate: {name}: new benchmark, no baseline yet")
    if compared == 0:
        print(
            "bench-gate: WARNING — no comparable benchmarks between baseline and current "
            "(size-mismatched baseline?); the gate is NOT protecting anything. Commit a "
            "baseline from the same --quick configuration CI runs."
        )
        return 0

    if regressions:
        print(
            f"bench-gate: FAIL — {len(regressions)} benchmark(s) regressed past "
            f"{args.threshold:.2f}x:"
        )
        for name, base, cur, ratio in regressions:
            print(f"  {name}: {base:.4f}s -> {cur:.4f}s ({ratio:.2f}x)")
        return 1
    print("bench-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
